//! Straggler-tolerance perf for speculative over-scheduling (DESIGN.md
//! §11): rounds/sec with 0 / 1 / 2 of 7 workers stalled (0 / ~10 / ~30%
//! nominal), at overschedule ε = 0 / 1 / 2.
//!
//! Spawns 7 scripted protocol workers (real TCP, real frames, no local
//! training); a "stalled" worker sleeps `STALL_MS` after every broadcast
//! before reporting — slow, not dead, exactly the failure mode the
//! speculation targets. The committed `BENCH_straggler.json` records the
//! grid; wall-clock cells are filled by
//! `cargo bench --bench bench_straggler` (results/bench/straggler.json).
//!
//! Asserted structurally on every run:
//!
//! - the clean cell (ε = 0, no stalls) commits every round with zero
//!   casualties and zero cancellations — today's path, untouched;
//! - with ε = 2 covering the stalled 30%, rounds are cancelled (not
//!   casualties) and the run never waits out a stall: wall-clock stays
//!   within 2x of the ε = 2 no-stall baseline (plus a small absolute
//!   slack for sub-50ms jitter), and is far under the non-speculative
//!   30% cell;
//! - the non-speculative 30% cell degrades by at least two full stall
//!   windows — the cost the speculation buys back.

use ragek::bench::Bench;
use ragek::config::ExperimentConfig;
use ragek::coordinator::engine::{ClientPool, RoundEngine};
use ragek::fl::codec::Codec;
use ragek::fl::distributed::TcpClientPool;
use ragek::fl::transport::{recv, send, Msg};
use ragek::sparse::SparseVec;
use ragek::util::json::Json;
use std::net::{TcpListener, TcpStream};
use std::thread;
use std::time::Duration;

const N: usize = 7;
const ROUNDS: usize = 4;
const STALL_MS: u64 = 300;
/// stalled-worker counts for the 0 / ~10 / ~30% grid over 7 workers
const STALLS: [usize; 3] = [0, 1, 2];
const EPSILONS: [usize; 3] = [0, 1, 2];

fn scenario(overschedule: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::mnist_smoke();
    cfg.n_clients = N;
    cfg.rounds = ROUNDS;
    cfg.participation = 0.71; // ceil(4.97) = 5 of 7 per round
    cfg.overschedule = overschedule;
    cfg.recluster_every = 0;
    cfg.eval_every = 0;
    cfg.train_n = 200;
    cfg.test_n = 64;
    cfg.io_timeout_ms = 30_000; // stalls are slow, never dead
    cfg
}

/// A scripted worker: joins, then answers every broadcast with a fixed
/// 12-index report and the echoed request; with `stall_ms > 0` it sleeps
/// before reporting, every round. Cancel `Sit` frames are skipped like
/// the real worker's, and a torn-down stream (the PS may kill a straggler
/// it catches mid-write) ends the script cleanly.
fn scripted_worker(port: u16, id: u32, stall_ms: u64) -> thread::JoinHandle<anyhow::Result<()>> {
    thread::spawn(move || {
        let mut s = TcpStream::connect(("127.0.0.1", port))?;
        send(&mut s, &Msg::Join { client_id: id, codec: Codec::Raw }, Codec::Raw)?;
        let base = 13 * id; // disjoint per-client index windows
        let idx: Vec<u32> = (0..12u32).map(|j| base + j).collect();
        let val: Vec<f32> = (0..12).map(|j| (12 - j) as f32).collect();
        let report = SparseVec::new(idx, val);
        loop {
            let msg = match recv(&mut s, Codec::Raw) {
                Ok(m) => m,
                Err(_) => return Ok(()), // stream torn down: clean end
            };
            match msg {
                Msg::Model { round, .. } => {
                    if stall_ms > 0 {
                        thread::sleep(Duration::from_millis(stall_ms));
                    }
                    let rep = Msg::Report {
                        client_id: id,
                        round,
                        report: report.clone(),
                        mean_loss: 1.0,
                    };
                    if send(&mut s, &rep, Codec::Raw).is_err() {
                        return Ok(());
                    }
                    match recv(&mut s, Codec::Raw) {
                        Ok(Msg::Request { indices, .. }) => {
                            let update =
                                ragek::fl::client::Client::answer_request(&report, &indices);
                            let msg = Msg::Update { client_id: id, round, update };
                            if send(&mut s, &msg, Codec::Raw).is_err() {
                                return Ok(());
                            }
                        }
                        Ok(Msg::Sit { .. }) => continue, // cancelled post-report
                        Ok(Msg::Shutdown) => return Ok(()),
                        Ok(other) => anyhow::bail!("worker {id}: unexpected {other:?}"),
                        Err(_) => return Ok(()),
                    }
                }
                Msg::Sit { .. } => continue,
                Msg::Shutdown => return Ok(()),
                other => anyhow::bail!("worker {id}: unexpected {other:?}"),
            }
        }
    })
}

struct Cell {
    mean_s: f64,
    casualties: usize,
    cancelled: usize,
}

fn run_cell(b: &mut Bench, n_stall: usize, eps: usize) -> anyhow::Result<Cell> {
    let cfg = scenario(eps);
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let port = listener.local_addr()?.port();
    let workers: Vec<_> = (0..N)
        .map(|i| scripted_worker(port, i as u32, if i < n_stall { STALL_MS } else { 0 }))
        .collect();
    let mut pool = TcpClientPool::accept(&cfg, listener)?;
    let init = pool.backend().init_params()?;
    let mut engine = RoundEngine::new(&cfg, init);

    let (mut casualties, mut cancelled) = (0usize, 0usize);
    let mean_s = b
        .run_once(&format!("{ROUNDS} rounds stalled={n_stall} eps={eps}"), || {
            for _ in 0..ROUNDS {
                let out = engine.run_round(&mut pool).unwrap();
                casualties += out.casualties.len();
                cancelled += out.cancelled.len();
            }
        })
        .mean();
    pool.shutdown()?;
    for w in workers {
        w.join().unwrap()?;
    }
    assert_eq!(engine.round(), ROUNDS, "stalled={n_stall} eps={eps}: every round must commit");
    Ok(Cell { mean_s, casualties, cancelled })
}

fn main() -> anyhow::Result<()> {
    let mut b = Bench::new("straggler");

    println!(
        "\nspeculative over-scheduling vs stalled workers \
         ({N} workers, m = 5, {ROUNDS} rounds, {STALL_MS} ms stalls):"
    );
    println!(
        "{:<10} {:<6} {:>12} {:>12} {:>12}",
        "stalled", "eps", "rounds/sec", "casualties", "cancelled"
    );
    let mut table = Vec::new();
    let mut grid = std::collections::HashMap::new();
    for &n_stall in &STALLS {
        for &eps in &EPSILONS {
            let cell = run_cell(&mut b, n_stall, eps)?;
            let rps = ROUNDS as f64 / cell.mean_s;
            println!(
                "{:<10} {eps:<6} {rps:>12.2} {:>12} {:>12}",
                format!("{n_stall}/{N}"),
                cell.casualties,
                cell.cancelled
            );
            table.push(Json::obj(vec![
                ("stalled_workers", Json::Num(n_stall as f64)),
                ("stalled_frac", Json::Num(n_stall as f64 / N as f64)),
                ("overschedule", Json::Num(eps as f64)),
                ("rounds", Json::Num(ROUNDS as f64)),
                ("stall_ms", Json::Num(STALL_MS as f64)),
                ("rounds_per_sec", Json::Num(rps)),
                ("casualties", Json::Num(cell.casualties as f64)),
                ("cancelled", Json::Num(cell.cancelled as f64)),
            ]));
            grid.insert((n_stall, eps), cell);
        }
    }

    // ---- the structural pins
    let clean = &grid[&(0, 0)];
    assert_eq!(clean.casualties, 0, "clean cell: a healthy fleet has no casualties");
    assert_eq!(clean.cancelled, 0, "clean cell: epsilon = 0 never cancels");
    // with everyone fast, reports race the commit: whoever lands in the
    // same poll batch as the quota-filling report still commits, so the
    // cancel count is bounded by epsilon per round, never asserted exact
    let spec_base = &grid[&(0, 2)];
    assert_eq!(spec_base.casualties, 0, "eps=2, all fast: cancels are never casualties");
    assert!(spec_base.cancelled <= ROUNDS * 2, "at most epsilon cancels per round");
    let spec = &grid[&(2, 2)];
    assert!(spec.cancelled > 0, "speculation must cancel the stragglers, not wait them out");
    let blocking = &grid[&(2, 0)];
    let stall_s = STALL_MS as f64 / 1000.0;
    assert!(
        blocking.mean_s >= clean.mean_s + 2.0 * stall_s,
        "the non-speculative path must degrade by >= two stall windows: \
         {:.3}s vs clean {:.3}s",
        blocking.mean_s,
        clean.mean_s
    );
    // the acceptance pin: with eps = 2 covering the stalled 30%, the run
    // stays within 2x of its own no-stall baseline (50 ms jitter floor)
    // — it commits on the fast majority instead of waiting out stalls
    assert!(
        spec.mean_s <= 2.0 * spec_base.mean_s.max(0.05),
        "speculative rounds must not wait out stalls: {:.3}s vs baseline {:.3}s",
        spec.mean_s,
        spec_base.mean_s
    );
    assert!(
        2.0 * spec.mean_s < blocking.mean_s,
        "speculation must beat the blocking path at 30% stalled: \
         {:.3}s vs {:.3}s",
        spec.mean_s,
        blocking.mean_s
    );
    println!(
        "(speculation pins hold: eps=2 at 30% stalled runs {:.1}x faster than eps=0)",
        blocking.mean_s / spec.mean_s
    );

    // machine-readable grid next to the timing results
    let dir = std::path::Path::new("results/bench");
    if std::fs::create_dir_all(dir).is_ok() {
        let j = Json::obj(vec![("grid", Json::Arr(table))]);
        let path = dir.join("straggler_table.json");
        let _ = std::fs::write(&path, j.to_pretty());
        println!("  -> {}", path.display());
    }

    b.save();
    Ok(())
}
