//! Hierarchical-topology perf: shard-count scaling of the two-level
//! round driver (DESIGN.md §7) on the standard 8-client MNIST scenario
//! (`ragek::bench::sharding` — shared with `bench_end2end` so the config
//! and thresholds cannot drift apart).
//!
//! Measures wall-clock per round for flat vs sharded x{1, 2, 4} under
//! the parallel shard driver, the serial-vs-parallel shard-drive gap at 4
//! shards, and prints the deterministic aggregate bytes/round table — the
//! §6/§7 counters are **identical across topologies** (the root <-> shard
//! hop is in-process, zero wire bytes), which this bench asserts and
//! `BENCH_sharding.json` records as the committed baseline.

use ragek::bench::{sharding, Bench};
use ragek::fl::metrics::CommStats;
use ragek::fl::trainer::Trainer;

const ROUNDS: usize = 3;

fn main() -> anyhow::Result<()> {
    let mut b = Bench::new("sharding");

    // ---- shard-count scaling under the production (parallel) driver
    let mut comms: Vec<(String, CommStats)> = Vec::new();
    for shards in [0usize, 1, 2, 4] {
        let cfg = sharding::scenario(shards, ROUNDS);
        let label = match shards {
            0 => "flat".to_string(),
            s => format!("sharded x{s}"),
        };
        let mut t = Trainer::from_config(&cfg)?;
        b.run_once(&format!("{ROUNDS} rounds n=8 {label} (parallel driver)"), || {
            for _ in 0..ROUNDS {
                t.run_round().unwrap();
            }
        });
        comms.push((label, t.comm()));
    }

    // ---- deterministic aggregate bytes/round: identical at every shard
    // count (the committed BENCH_sharding.json table)
    println!("\naggregate bytes/round (raw codec, full participation, n=8):");
    println!(
        "{:<12} {:>12} {:>12} {:>12} {:>12}",
        "topology", "uplink", "downlink", "wire_up", "wire_down"
    );
    let flat = comms[0].1;
    for (label, comm) in &comms {
        println!(
            "{label:<12} {:>12} {:>12} {:>12} {:>12}",
            comm.uplink() / ROUNDS as u64,
            comm.downlink() / ROUNDS as u64,
            comm.wire_up / ROUNDS as u64,
            comm.wire_down / ROUNDS as u64
        );
        assert_eq!(
            (comm.uplink(), comm.downlink(), comm.wire_up, comm.wire_down),
            (flat.uplink(), flat.downlink(), flat.wire_up, flat.wire_down),
            "{label}: sharding must add zero protocol/wire bytes (§7 roll-up)"
        );
    }

    // ---- serial sum vs parallel shard drive at 4 shards (asserts the
    // parallelism floor on multi-core hosts)
    sharding::drive_comparison(&mut b, ROUNDS)?;

    b.save();
    Ok(())
}
