//! Fig. 3 regeneration harness: MNIST accuracy (a) and loss (b) series
//! for rAge-k vs rTop-k at identical (r=75, k=10) bandwidth — prints the
//! two curves and the headline comparison rows.

use ragek::bench::Bench;
use ragek::config::{EvalMode, ExperimentConfig};
use ragek::coordinator::strategies::StrategyKind;
use ragek::fl::metrics::History;
use ragek::fl::trainer::Trainer;

fn main() -> anyhow::Result<()> {
    let mut b = Bench::new("fig3_mnist");
    b.min_secs = 0.0;

    // defaults match the recorded EXPERIMENTS.md §F3 run (150 rounds,
    // train_n 4000); note §F3's seed table — single-seed runs carry
    // +-5 pt noise and rAge-k's win is the 3-seed mean
    let rounds: usize = std::env::var("FIG3_ROUNDS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(150);

    let mut histories: Vec<History> = Vec::new();
    for strategy in [StrategyKind::RageK, StrategyKind::RTopK] {
        let mut cfg = ExperimentConfig::mnist_scaled();
        cfg.rounds = rounds;
        cfg.eval_every = 5;
        cfg.eval_mode = EvalMode::Global;
        cfg.strategy = strategy;
        b.run_once(&format!("{} {rounds}-round run", strategy.name()), || {
            let mut t = Trainer::from_config(&cfg).unwrap();
            histories.push(t.run().unwrap().history);
        });
    }

    println!("\n[fig3a] accuracy series (global model, eval every 5 rounds):");
    for h in &histories {
        let series: Vec<String> =
            h.acc_series().iter().map(|a| format!("{:.3}", a)).collect();
        println!("  {:<10} {}", h.name, series.join(" "));
    }
    println!("[fig3b] train-loss series:");
    for h in &histories {
        let series: Vec<String> =
            h.loss_series().iter().step_by(5).map(|l| format!("{l:.3}")).collect();
        println!("  {:<10} {}", h.name, series.join(" "));
    }
    println!("\n[fig3] headline:");
    for h in &histories {
        println!(
            "  {:<10} final acc {:5.2}%  rounds-to-50% {:?}  uplink {:.2} MiB",
            h.name,
            h.final_accuracy() * 100.0,
            h.rounds_to_accuracy(0.5),
            h.comm.uplink() as f64 / (1 << 20) as f64
        );
    }
    let (ragek, rtopk) = (&histories[0], &histories[1]);
    println!(
        "  shape check (paper: rAge-k dominates; single-seed noise +-5pt — \
         see EXPERIMENTS.md §F3 for the 3-seed table): {}",
        if ragek.final_accuracy() >= rtopk.final_accuracy() { "HOLDS" } else { "INVERTED on this seed" }
    );
    b.save();
    Ok(())
}
