//! Fig. 2 regeneration harness: the MNIST connectivity heatmaps at the
//! paper's snapshot iterations (1, 21, 41, 61) + time-to-recovery of the
//! planted pairs. Prints the same matrix series the paper plots.

use ragek::bench::Bench;
use ragek::config::ExperimentConfig;
use ragek::data::partition::paper_pair_truth;
use ragek::fl::trainer::Trainer;
use ragek::util::plot;

fn main() -> anyhow::Result<()> {
    let mut b = Bench::new("fig2_clustering");

    let mut cfg = ExperimentConfig::mnist_scaled();
    cfg.rounds = 61;
    cfg.train_n = 2000;
    cfg.test_n = 256;
    cfg.eval_every = 0;

    let mut heatmaps = Vec::new();
    let mut labels = Vec::new();
    b.min_secs = 0.0; // one timed full run is the measurement
    b.run_once("mnist 61-round clustering run", || {
        let mut t = Trainer::from_config(&cfg).unwrap();
        t.heatmap_rounds = vec![1, 21, 41, 61];
        let report = t.run().unwrap();
        heatmaps = report.heatmaps;
        labels = report.cluster_labels;
    });

    let truth = paper_pair_truth(cfg.n_clients);
    println!("\n[fig2] ground-truth pairs: {truth:?}");
    for (round, m) in &heatmaps {
        println!("\n[fig2] connectivity matrix @ iteration {round} (paper Fig. 2):");
        println!("{}", plot::heatmap(m, true));
        print!("[fig2] csv:\n{}", plot::matrix_csv(m));
    }
    println!("\n[fig2] clusters found: {labels:?}");
    println!(
        "[fig2] pairs recovered: {}",
        if labels == truth { "YES (matches paper)" } else { "partially" }
    );
    b.save();
    Ok(())
}
