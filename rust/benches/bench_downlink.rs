//! Downlink cost: dense model broadcasts vs generation-addressed delta
//! broadcasts (DESIGN.md §9) on the standard 8-client MNIST scenario
//! (`ragek::bench::sharding::scenario` — shared with `bench_sharding` so
//! the config cannot drift).
//!
//! Runs the same fixed-seed training schedule once per (topology,
//! participation, downlink) cell and prints the deterministic aggregate
//! bytes/round table. Wire accounting is exact frame arithmetic (pinned
//! equal to `encode().len()` by the transport tests), so the table is
//! reproducible run to run and `BENCH_downlink.json` records it as the
//! committed baseline. Asserts, per cell:
//!
//! - delta `wire_down` at least 20x below dense (the PR's headline win),
//! - uplink/`wire_up` byte-identical dense vs delta (downlink-only knob).

use ragek::bench::{sharding, Bench};
use ragek::config::{Downlink, Payload};
use ragek::fl::metrics::CommStats;
use ragek::fl::trainer::Trainer;
use ragek::util::json::Json;

const ROUNDS: usize = 4;

/// The PR's regression floor for the standard scenario (analytically
/// ~219x at full participation: 1,272,912 B/round dense vs ~5,808 delta).
const RATIO_FLOOR: f64 = 20.0;

fn run_cell(
    shards: usize,
    participation: f64,
    downlink: Downlink,
    b: &mut Bench,
    label: &str,
) -> anyhow::Result<CommStats> {
    let mut cfg = sharding::scenario(shards, ROUNDS);
    cfg.participation = participation;
    cfg.downlink = downlink;
    // the delta downlink needs an index-sparse server apply (grad+adam
    // moves parameters outside the uploaded union); both cells of a
    // dense/delta pair share the payload so the comparison is exact
    cfg.payload = Payload::Delta;
    let mut t = Trainer::from_config(&cfg)?;
    b.run_once(label, || {
        for _ in 0..ROUNDS {
            t.run_round().unwrap();
        }
    });
    Ok(t.comm())
}

fn main() -> anyhow::Result<()> {
    let mut b = Bench::new("downlink");

    let mut table = Vec::new();
    println!("\naggregate bytes/round (raw codec, n=8, {ROUNDS} rounds):");
    println!(
        "{:<24} {:>14} {:>14} {:>8}",
        "cell", "dense down", "delta down", "ratio"
    );
    for (tag, shards, participation) in [
        ("flat p=1.0", 0usize, 1.0f64),
        ("flat p=0.5", 0, 0.5),
        ("sharded-x2 p=1.0", 2, 1.0),
        ("sharded-x2 p=0.5", 2, 0.5),
    ] {
        let dense_label = format!("{ROUNDS} rounds {tag} dense");
        let dense = run_cell(shards, participation, Downlink::Dense, &mut b, &dense_label)?;
        let delta_label = format!("{ROUNDS} rounds {tag} delta");
        let delta = run_cell(shards, participation, Downlink::Delta, &mut b, &delta_label)?;
        let r = ROUNDS as u64;
        let ratio = dense.wire_down as f64 / delta.wire_down.max(1) as f64;
        println!(
            "{tag:<24} {:>14} {:>14} {:>7.1}x",
            dense.wire_down / r,
            delta.wire_down / r,
            ratio
        );
        assert!(
            ratio >= RATIO_FLOOR,
            "{tag}: delta downlink ratio {ratio:.1}x regressed below {RATIO_FLOOR}x \
             (dense {} B vs delta {} B over {ROUNDS} rounds)",
            dense.wire_down,
            delta.wire_down
        );
        assert_eq!(
            (dense.uplink(), dense.wire_up),
            (delta.uplink(), delta.wire_up),
            "{tag}: the downlink knob must not change a single uplink byte"
        );
        table.push(Json::obj(vec![
            ("cell", Json::Str(tag.to_string())),
            ("shards", Json::Num(shards as f64)),
            ("participation", Json::Num(participation)),
            ("rounds", Json::Num(ROUNDS as f64)),
            ("dense_wire_down_per_round", Json::Num((dense.wire_down / r) as f64)),
            ("delta_wire_down_per_round", Json::Num((delta.wire_down / r) as f64)),
            ("ratio", Json::Num(ratio)),
            ("wire_up_per_round", Json::Num((dense.wire_up / r) as f64)),
        ]));
    }
    println!("(ratio floor asserted: >= {RATIO_FLOOR}x in every cell)");

    // machine-readable bytes table next to the timing results
    let dir = std::path::Path::new("results/bench");
    if std::fs::create_dir_all(dir).is_ok() {
        let j = Json::obj(vec![("bytes_per_round", Json::Arr(table))]);
        let path = dir.join("downlink_bytes.json");
        let _ = std::fs::write(&path, j.to_pretty());
        println!("  -> {}", path.display());
    }

    b.save();
    Ok(())
}
