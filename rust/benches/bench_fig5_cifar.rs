//! Fig. 5 regeneration harness: CIFAR10 accuracy/loss series, rAge-k vs
//! rTop-k at (r=2500, k=100), PJRT/XLA backend, reduced scale by default
//! (FIG5_ROUNDS to scale up; the paper runs to iteration 1400).
//! Skips without artifacts.

use ragek::bench::Bench;
use ragek::config::{EvalMode, ExperimentConfig};
use ragek::coordinator::strategies::StrategyKind;
use ragek::fl::metrics::History;
use ragek::fl::trainer::Trainer;

fn main() -> anyhow::Result<()> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("bench_fig5: artifacts/ not built (run `make artifacts`); skipping");
        return Ok(());
    }
    let mut b = Bench::new("fig5_cifar");
    b.min_secs = 0.0;

    // default kept tiny (see bench_fig4); recorded run: EXPERIMENTS.md §F5
    let rounds: usize = std::env::var("FIG5_ROUNDS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2);

    let mut histories: Vec<History> = Vec::new();
    for strategy in [StrategyKind::RageK, StrategyKind::RTopK] {
        let mut cfg = ExperimentConfig::cifar_paper();
        cfg.rounds = rounds;
        cfg.h = 4;
        cfg.recluster_every = (rounds / 2).max(2);
        cfg.train_n = 600;
        cfg.test_n = 128;
        cfg.eval_every = 1;
        cfg.eval_mode = EvalMode::Global;
        cfg.strategy = strategy;
        b.run_once(&format!("{} {rounds}-round CNN run", strategy.name()), || {
            let mut t = Trainer::from_config(&cfg).unwrap();
            histories.push(t.run().unwrap().history);
        });
    }

    println!("\n[fig5a] accuracy series:");
    for h in &histories {
        let series: Vec<String> =
            h.acc_series().iter().map(|a| format!("{a:.3}")).collect();
        println!("  {:<10} {}", h.name, series.join(" "));
    }
    println!("[fig5b] train-loss series:");
    for h in &histories {
        let series: Vec<String> =
            h.loss_series().iter().map(|l| format!("{l:.3}")).collect();
        println!("  {:<10} {}", h.name, series.join(" "));
    }
    for h in &histories {
        println!(
            "  {:<10} final acc {:5.2}%  uplink {:.2} MiB",
            h.name,
            h.final_accuracy() * 100.0,
            h.comm.uplink() as f64 / (1 << 20) as f64
        );
    }
    b.save();
    Ok(())
}
