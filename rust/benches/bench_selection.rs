//! Hot-path micro-bench: the per-round selection pipeline at the paper's
//! two scales — client top-r scan (d -> r) and PS age-ranked choice
//! (r -> k), incl. the disjoint cluster variant.

use ragek::age::AgeVector;
use ragek::bench::Bench;
use ragek::coordinator::selection::{select_disjoint, select_oldest_k};
use ragek::sparse::topk_abs_sparse;
use ragek::util::rng::Rng;

fn main() {
    let mut b = Bench::new("selection");
    let mut rng = Rng::new(0);

    for (tag, d, r, k) in [
        ("mnist  d=39760  r=75   k=10 ", 39760usize, 75usize, 10usize),
        ("cifar  d=2.5M   r=2500 k=100", 2_515_338, 2500, 100),
    ] {
        let mut grad = vec![0.0f32; d];
        rng.fill_gaussian(&mut grad, 1.0);

        b.run_units(&format!("client.topr_abs      {tag}"), Some(d as f64), || {
            std::hint::black_box(topk_abs_sparse(&grad, r));
        });

        let mut age = AgeVector::new(d);
        for round in 0..50u32 {
            let sel: Vec<u32> = (0..k as u32).map(|i| (i * 37 + round * 911) % d as u32).collect();
            age.update(&sel);
        }
        let report = topk_abs_sparse(&grad, r);

        b.run_units(&format!("ps.select_oldest_k   {tag}"), Some(r as f64), || {
            std::hint::black_box(select_oldest_k(&age, &report.idx, k));
        });

        // a 2-member cluster (the paper's pair structure)
        let mut grad2 = vec![0.0f32; d];
        rng.fill_gaussian(&mut grad2, 1.0);
        let report2 = topk_abs_sparse(&grad2, r);
        let reports: Vec<&[u32]> = vec![&report.idx, &report2.idx];
        b.run_units(&format!("ps.select_disjoint x2 {tag}"), Some(2.0 * r as f64), || {
            std::hint::black_box(select_disjoint(&age, &reports, k));
        });

        // a 6-member cluster with heavy report overlap: the regime where
        // the old HashSet + O(k) sel.contains scans dominated and the
        // stamp-vector rewrite pays off (overlap forces the fallback
        // pass, the former quadratic corner)
        let shared = topk_abs_sparse(&grad, r); // everyone reports the same set
        let big: Vec<&[u32]> = (0..6).map(|_| shared.idx.as_slice()).collect();
        b.run_units(
            &format!("ps.select_disjoint x6 overlapped {tag}"),
            Some(6.0 * r as f64),
            || {
                std::hint::black_box(select_disjoint(&age, &big, k));
            },
        );
    }
    b.save();
}
