//! Fleet-membership perf: what mid-round drops cost the round loop.
//!
//! Runs the standard 8-client MNIST scenario through the deterministic
//! chaos harness (`ragek::testing::FlakyPool`) at 0%, 10%, and 30%
//! per-phase drop rates and reports rounds/sec — the committed
//! `BENCH_membership.json` baseline. Every round must commit regardless
//! of the chaos (drop-and-continue: the engine finishes with the
//! survivors, casualties' ages keep growing per eq. 2), and the clean
//! run must see zero casualties (the all-answer path pays nothing for
//! the membership machinery).

use ragek::bench::Bench;
use ragek::config::ExperimentConfig;
use ragek::coordinator::engine::RoundEngine;
use ragek::testing::FlakyPool;

const ROUNDS: usize = 6;

fn scenario() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::mnist_scaled();
    cfg.n_clients = 8;
    cfg.parallel = 1;
    cfg.rounds = ROUNDS;
    cfg.train_n = 2000;
    cfg.test_n = 256;
    cfg.eval_every = 0;
    cfg
}

fn main() -> anyhow::Result<()> {
    let mut b = Bench::new("membership");

    println!("\nrounds/sec under simulated drops (n=8, {ROUNDS} rounds, rejoin after 2):");
    println!("{:<12} {:>12} {:>12} {:>10}", "drop rate", "rounds/sec", "casualties", "rejoins");
    for (label, rate) in [("0%", 0.0f32), ("10%", 0.10), ("30%", 0.30)] {
        let cfg = scenario();
        let (mut pool, init) = FlakyPool::new(&cfg, rate, 2, 0xC1A05)?;
        let mut engine = RoundEngine::new(&cfg, init);
        let mut casualties = 0usize;
        let mean = b
            .run_once(&format!("{ROUNDS} rounds n=8, {label} drops"), || {
                for _ in 0..ROUNDS {
                    casualties += engine.run_round(&mut pool).unwrap().casualties.len();
                }
            })
            .mean();
        let rejoins: u32 = (0..cfg.n_clients).map(|i| engine.fleet().generation(i)).sum();
        println!(
            "{label:<12} {:>12.2} {casualties:>12} {rejoins:>10}",
            ROUNDS as f64 / mean
        );
        // drop-and-continue: every round commits, chaos or not
        assert_eq!(engine.round(), ROUNDS, "{label}: every round must commit");
        if rate <= 0.0 {
            assert_eq!(casualties, 0, "a clean fleet has no casualties");
        } else if rate >= 0.30 {
            // at 30% per phase over 6 rounds x 8 clients the (seeded,
            // deterministic) plan drops someone with overwhelming margin
            assert!(casualties > 0, "the chaos plan must bite");
        }
    }

    b.save();
    Ok(())
}
