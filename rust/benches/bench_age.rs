//! eq. (2) age-sweep cost at the paper's two model sizes, plus merge and
//! frequency bookkeeping — the d-dimensional PS state the paper adds
//! over plain rTop-k.

use ragek::age::{AgeVector, FrequencyVector};
use ragek::bench::Bench;

fn main() {
    let mut b = Bench::new("age");

    for (tag, d, k) in [
        ("mnist d=39760  k=10 ", 39760usize, 10usize),
        ("cifar d=2.5M   k=100", 2_515_338, 100),
    ] {
        let sel: Vec<u32> = (0..k as u32).map(|i| i * 31 % d as u32).collect();
        let mut age = AgeVector::new(d);
        b.run_units(&format!("age.update (eq.2)   {tag}"), Some(d as f64), || {
            age.update(&sel);
        });

        let other = age.clone();
        let mut target = age.clone();
        b.run_units(&format!("age.merge_min       {tag}"), Some(d as f64), || {
            target.merge_min(&other);
        });

        b.run_units(&format!("age.gather r=2500   {tag}"), Some(2500.0), || {
            let idx: Vec<u32> = (0..2500u32).map(|i| i * 97 % d as u32).collect();
            std::hint::black_box(age.gather(&idx));
        });
    }

    // frequency vectors stay sparse: dot cost depends on rounds recorded
    for rounds in [10usize, 100, 1000] {
        let mut f1 = FrequencyVector::new();
        let mut f2 = FrequencyVector::new();
        for rd in 0..rounds {
            let idx: Vec<u32> = (0..10u32).map(|i| (i + rd as u32 * 7) % 39760).collect();
            f1.record(&idx);
            f2.record(&idx);
        }
        b.run(&format!("freq.dot after {rounds:>4} rounds (nnz={})", f1.nnz()), || {
            std::hint::black_box(f1.dot(&f2));
        });
    }
    b.save();
}
