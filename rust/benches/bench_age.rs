//! eq. (2) age bookkeeping cost at the paper's two model sizes: the lazy
//! O(k) epoch-offset update vs the dense O(d) sweep it replaced (the
//! per-cluster, per-round PS cost the paper adds over plain rTop-k),
//! plus merge, gather and frequency bookkeeping.

use ragek::age::{AgeVector, DenseAgeVector, FrequencyVector};
use ragek::bench::Bench;

fn main() {
    let mut b = Bench::new("age");

    for (tag, d, k) in [
        ("mnist d=39760  k=10 ", 39760usize, 10usize),
        ("cifar d=2.5M   k=100", 2_515_338, 100),
    ] {
        let sel: Vec<u32> = (0..k as u32).map(|i| i * 31 % d as u32).collect();

        // the hot path: one eq. (2) update per cluster per round
        let mut lazy = AgeVector::new(d);
        b.run_units(&format!("age.update lazy  O(k) {tag}"), Some(k as f64), || {
            lazy.update(&sel);
        });
        let mut dense = DenseAgeVector::new(d);
        b.run_units(&format!("age.update dense O(d) {tag}"), Some(d as f64), || {
            dense.update(&sel);
        });

        // merge only happens on (M-periodic) cluster formation
        let other = lazy.clone();
        let mut target = lazy.clone();
        b.run_units(&format!("age.merge_min        {tag}"), Some(d as f64), || {
            target.merge_min(&other);
        });

        b.run_units(&format!("age.gather r=2500    {tag}"), Some(2500.0), || {
            let idx: Vec<u32> = (0..2500u32).map(|i| i * 97 % d as u32).collect();
            std::hint::black_box(lazy.gather(&idx));
        });
    }

    // frequency vectors stay sparse: dot cost depends on rounds recorded
    for rounds in [10usize, 100, 1000] {
        let mut f1 = FrequencyVector::new();
        let mut f2 = FrequencyVector::new();
        for rd in 0..rounds {
            let idx: Vec<u32> = (0..10u32).map(|i| (i + rd as u32 * 7) % 39760).collect();
            f1.record(&idx);
            f2.record(&idx);
        }
        b.run(&format!("freq.dot after {rounds:>4} rounds (nnz={})", f1.nnz()), || {
            std::hint::black_box(f1.dot(&f2));
        });
    }
    b.save();
}
