//! Wire-codec micro-bench: encode/decode throughput for the round's
//! frames under every codec, plus the deterministic bytes/round table
//! (raw vs packed vs packed-f16) that `BENCH_transport.json` tracks.
//!
//! The bytes table uses fixed index patterns (evenly spaced and
//! clustered top-r sets at the paper's MNIST/CIFAR shapes), so its
//! numbers are exactly reproducible run to run — frame sizes come from
//! the arithmetic `*_frame_bytes` helpers that are pinned equal to
//! `encode().len()` by the transport tests.

use ragek::bench::Bench;
use ragek::fl::codec::{Codec, IndexScratch};
use ragek::fl::transport::{
    decode_model_into, encode_model_frame_into, model_frame_bytes, report_frame_bytes,
    request_frame_bytes, update_frame_bytes, Msg, SIT_FRAME_BYTES,
};
use ragek::sparse::SparseVec;
use ragek::util::json::Json;

const ALL: [Codec; 3] = [Codec::Raw, Codec::Packed, Codec::PackedF16];

/// r indices spread uniformly over [0, d).
fn evenly_spaced(d: usize, r: usize) -> Vec<u32> {
    let step = (d / r).max(1) as u32;
    (0..r as u32).map(|i| i * step).collect()
}

/// r indices in 5 dense runs (the layer-clustered regime age-based
/// selection produces), interleaved across clusters so the list is in a
/// report-like non-sorted order.
fn clustered(d: usize, r: usize) -> Vec<u32> {
    let clusters = 5usize;
    let per = r.div_ceil(clusters);
    let stride = (d / clusters) as u32;
    let mut idx = Vec::with_capacity(r);
    for j in 0..per {
        for c in 0..clusters {
            if idx.len() < r {
                idx.push(c as u32 * stride + j as u32);
            }
        }
    }
    idx
}

fn main() {
    let mut b = Bench::new("transport");

    // ---- dense model frame: bulk encode/decode at MNIST scale
    let d = 39760usize;
    let params: Vec<f32> = (0..d).map(|i| (i as f32).sin()).collect();
    let mut frame = Vec::new();
    b.run_units("model.encode  d=39760 (bulk)", Some(4.0 * d as f64), || {
        encode_model_frame_into(3, &params, &mut frame);
        std::hint::black_box(&frame);
    });
    let mut decoded: Vec<f32> = Vec::new();
    b.run_units("model.decode  d=39760 (bulk)", Some(4.0 * d as f64), || {
        std::hint::black_box(decode_model_into(&frame[8..], &mut decoded).unwrap());
    });
    assert_eq!(decoded, params, "bulk roundtrip must be exact");

    // ---- sparse frames at both paper shapes, every codec
    for (tag, d, r, k) in [
        ("mnist d=39760  r=75   k=10 ", 39760usize, 75usize, 10usize),
        ("cifar d=2.5M   r=2500 k=100", 2_515_338, 2500, 100),
    ] {
        let idx = clustered(d, r);
        let val: Vec<f32> = idx.iter().map(|&j| (j as f32 * 1e-4).cos()).collect();
        let report = Msg::Report {
            client_id: 1,
            round: 2,
            report: SparseVec::new(idx.clone(), val.clone()),
            mean_loss: 0.5,
        };
        let update = Msg::Update {
            client_id: 1,
            round: 2,
            update: SparseVec::new(idx[..k].to_vec(), val[..k].to_vec()),
        };
        for codec in ALL {
            let mut out = Vec::new();
            let mut scratch = IndexScratch::default();
            b.run_units(
                &format!("report.encode {tag} {}", codec.name()),
                Some(r as f64),
                || {
                    report.encode_into(codec, &mut out, &mut scratch);
                    std::hint::black_box(&out);
                },
            );
            let payload = report.encode(codec)[8..].to_vec();
            b.run_units(
                &format!("report.decode {tag} {}", codec.name()),
                Some(r as f64),
                || {
                    std::hint::black_box(Msg::decode(&payload, codec).unwrap());
                },
            );
            let up_payload = update.encode(codec)[8..].to_vec();
            b.run_units(
                &format!("update.decode {tag} {}", codec.name()),
                Some(k as f64),
                || {
                    std::hint::black_box(Msg::decode(&up_payload, codec).unwrap());
                },
            );
        }
    }

    // ---- deterministic bytes/round table (tracked in BENCH_transport.json)
    let mut table = Vec::new();
    println!("\nbytes per round per cohort client (deterministic patterns):");
    println!(
        "{:<30} {:>10} {:>10} {:>10} {:>8}",
        "scenario", "raw", "packed", "packed-f16", "ratio"
    );
    // per-scenario regression floor: >= 2x everywhere except the
    // adversarial evenly-spread CIFAR set, whose 2500 varint ranks cap
    // the win just below 2x (real age-selected sets are clustered)
    for (tag, d, r, k, floor) in [
        ("mnist-evenly", 39760usize, 75usize, 10usize, 2.0f64),
        ("mnist-clustered", 39760, 75, 10, 2.0),
        ("cifar-evenly", 2_515_338, 2500, 100, 1.9),
        ("cifar-clustered", 2_515_338, 2500, 100, 2.0),
    ] {
        let idx = if tag.ends_with("clustered") { clustered(d, r) } else { evenly_spaced(d, r) };
        let req = &idx[..k];
        let mut row = Vec::new();
        for codec in ALL {
            let uplink = report_frame_bytes(codec, &idx) + update_frame_bytes(codec, req);
            let downlink = model_frame_bytes(d) + request_frame_bytes(codec, req);
            row.push((uplink, downlink));
        }
        let ratio = row[0].0 as f64 / row[1].0 as f64;
        println!(
            "{:<30} {:>10} {:>10} {:>10} {:>7.2}x",
            format!("{tag} uplink"),
            row[0].0,
            row[1].0,
            row[2].0,
            ratio
        );
        assert!(
            ratio >= floor,
            "{tag}: packed uplink ratio {ratio:.2} regressed below {floor}"
        );
        table.push(Json::obj(vec![
            ("scenario", Json::Str(tag.to_string())),
            ("d", Json::Num(d as f64)),
            ("r", Json::Num(r as f64)),
            ("k", Json::Num(k as f64)),
            ("uplink_raw", Json::Num(row[0].0 as f64)),
            ("uplink_packed", Json::Num(row[1].0 as f64)),
            ("uplink_packed_f16", Json::Num(row[2].0 as f64)),
            ("downlink_raw", Json::Num(row[0].1 as f64)),
            ("downlink_packed", Json::Num(row[1].1 as f64)),
            ("downlink_packed_f16", Json::Num(row[2].1 as f64)),
            ("uplink_ratio_raw_over_packed", Json::Num(ratio)),
        ]));
    }
    println!("(sit frame: {SIT_FRAME_BYTES} B; downlink is model-dominated in every codec)");

    // machine-readable bytes table next to the timing results
    let dir = std::path::Path::new("results/bench");
    if std::fs::create_dir_all(dir).is_ok() {
        let j = Json::obj(vec![("bytes_per_round", Json::Arr(table))]);
        let path = dir.join("transport_bytes.json");
        let _ = std::fs::write(&path, j.to_pretty());
        println!("  -> {}", path.display());
    }

    b.save();
}
