//! Fig. 4 regeneration harness: CIFAR10 connectivity heatmaps (6 clients,
//! 3/3/4 label blocks) on the PJRT/XLA backend at reduced scale.
//! Skips without artifacts. Scale up with FIG4_ROUNDS / the
//! cifar_noniid example for the full run.

use ragek::bench::Bench;
use ragek::config::ExperimentConfig;
use ragek::data::partition::paper_pair_truth;
use ragek::fl::trainer::Trainer;
use ragek::util::plot;

fn main() -> anyhow::Result<()> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("bench_fig4: artifacts/ not built (run `make artifacts`); skipping");
        return Ok(());
    }
    let mut b = Bench::new("fig4_clustering");
    b.min_secs = 0.0;

    // default kept tiny: one CNN round is ~45 s on the 1-core testbed;
    // the recorded 6-round run lives in EXPERIMENTS.md §F4
    let rounds: usize = std::env::var("FIG4_ROUNDS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2);

    let mut cfg = ExperimentConfig::cifar_paper();
    cfg.rounds = rounds;
    cfg.h = 4;
    cfg.recluster_every = (rounds / 2).max(2);
    cfg.train_n = 600;
    cfg.test_n = 128;
    cfg.eval_every = 0;

    let mut heatmaps = Vec::new();
    let mut labels = Vec::new();
    b.run_once(&format!("cifar {rounds}-round clustering run (CNN d=2.5M via PJRT)"), || {
        let mut t = Trainer::from_config(&cfg).unwrap();
        t.heatmap_rounds = vec![1, rounds];
        let report = t.run().unwrap();
        heatmaps = report.heatmaps;
        labels = report.cluster_labels;
    });

    let truth = paper_pair_truth(cfg.n_clients);
    println!("\n[fig4] ground-truth pairs: {truth:?}");
    for (round, m) in &heatmaps {
        println!("\n[fig4] connectivity matrix @ iteration {round} (paper Fig. 4):");
        println!("{}", plot::heatmap(m, true));
        print!("[fig4] csv:\n{}", plot::matrix_csv(m));
    }
    println!("[fig4] clusters found: {labels:?}");
    b.save();
    Ok(())
}
