//! Connection-scale perf for the event-driven PS transport (DESIGN.md
//! §10): one `poll(2)` reactor, N worker connections.
//!
//! Spawns N scripted protocol workers (real TCP, real frames, no local
//! training — the threads answer instantly, so the measurement isolates
//! the PS-side transport cost) and drives the standard MNIST round loop
//! through one [`TcpClientPool`] at n = 8, 32, and 128 connections. The
//! committed `BENCH_connscale.json` records the table; wall-clock cells
//! are filled by `cargo bench --bench bench_connscale`
//! (results/bench/connscale.json).
//!
//! Asserted structurally on every run, at every scale:
//!
//! - every round commits with zero casualties (the reactor drives all N
//!   connections to completion);
//! - `model_encodes == rounds` — the broadcast is serialized **once**
//!   per round however many connections fan it out (the FrameRotation
//!   zero-copy pin survives the reactor);
//! - socket-observed bytes equal the engine's arithmetic mirror
//!   (`wire_observed == comm.wire_up/wire_down`), so the accounting
//!   pins hold off the happy path's thread-per-stream predecessor;
//! - downlink bytes per connection-round are identical across scales —
//!   the per-connection cost model is flat, which is the number the
//!   rounds/sec and RSS columns are judged against.

use ragek::bench::Bench;
use ragek::config::ExperimentConfig;
use ragek::coordinator::engine::{ClientPool, RoundEngine};
use ragek::fl::codec::Codec;
use ragek::fl::distributed::TcpClientPool;
use ragek::fl::transport::{recv, send, Msg};
use ragek::sparse::SparseVec;
use ragek::util::json::Json;
use std::net::{TcpListener, TcpStream};
use std::thread;

const ROUNDS: usize = 4;
const SIZES: [usize; 3] = [8, 32, 128];

fn scenario(n: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::mnist_smoke();
    cfg.n_clients = n;
    cfg.rounds = ROUNDS;
    cfg.participation = 1.0;
    cfg.recluster_every = 0; // singleton clusters: per-client selection
    cfg.eval_every = 0;
    cfg.train_n = 200;
    cfg.test_n = 64;
    cfg.io_timeout_ms = 30_000;
    cfg
}

/// A scripted worker: joins, then answers every broadcast with a fixed
/// 12-index report and the echoed request. No training, no sleeps — the
/// PS-side reactor is the only interesting cost left.
fn scripted_worker(port: u16, id: u32) -> thread::JoinHandle<anyhow::Result<()>> {
    thread::spawn(move || {
        let mut s = TcpStream::connect(("127.0.0.1", port))?;
        send(&mut s, &Msg::Join { client_id: id, codec: Codec::Raw }, Codec::Raw)?;
        let base = 13 * id; // disjoint per-client index windows
        let idx: Vec<u32> = (0..12u32).map(|j| base + j).collect();
        let val: Vec<f32> = (0..12).map(|j| (12 - j) as f32).collect();
        let report = SparseVec::new(idx, val);
        loop {
            match recv(&mut s, Codec::Raw)? {
                Msg::Model { round, .. } => {
                    send(
                        &mut s,
                        &Msg::Report {
                            client_id: id,
                            round,
                            report: report.clone(),
                            mean_loss: 1.0,
                        },
                        Codec::Raw,
                    )?;
                    let requested = match recv(&mut s, Codec::Raw)? {
                        Msg::Request { indices, .. } => indices,
                        other => anyhow::bail!("worker {id}: expected Request, got {other:?}"),
                    };
                    let update = ragek::fl::client::Client::answer_request(&report, &requested);
                    send(&mut s, &Msg::Update { client_id: id, round, update }, Codec::Raw)?;
                }
                Msg::Sit { .. } => continue,
                Msg::Shutdown => return Ok(()),
                other => anyhow::bail!("worker {id}: unexpected {other:?}"),
            }
        }
    })
}

/// Resident set size in kB from `/proc/self/status` (None off-Linux —
/// the column is informational, never asserted).
fn rss_kb() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("VmRSS:"))?;
    line.split_whitespace().nth(1)?.parse().ok()
}

fn main() -> anyhow::Result<()> {
    let mut b = Bench::new("connscale");

    println!("\none reactor, N connections ({ROUNDS} rounds, scripted workers):");
    println!(
        "{:<10} {:>12} {:>18} {:>16} {:>10}",
        "workers", "rounds/sec", "client-rounds/sec", "down B/conn-rnd", "RSS MB"
    );
    let mut table = Vec::new();
    let mut per_conn_down = Vec::new();
    for &n in &SIZES {
        let cfg = scenario(n);
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let port = listener.local_addr()?.port();
        let workers: Vec<_> = (0..n).map(|i| scripted_worker(port, i as u32)).collect();
        let mut pool = TcpClientPool::accept(&cfg, listener)?;
        let init = pool.backend().init_params()?;
        let mut engine = RoundEngine::new(&cfg, init);

        let mut casualties = 0usize;
        let mean = b
            .run_once(&format!("{ROUNDS} rounds n={n}"), || {
                for _ in 0..ROUNDS {
                    casualties += engine.run_round(&mut pool).unwrap().casualties.len();
                }
            })
            .mean();
        let rss = rss_kb();
        pool.shutdown()?;
        for w in workers {
            w.join().unwrap()?;
        }

        // ---- the structural pins (asserted at every scale)
        assert_eq!(engine.round(), ROUNDS, "n={n}: every round must commit");
        assert_eq!(casualties, 0, "n={n}: a healthy fleet must see zero casualties");
        assert_eq!(
            pool.model_encodes(),
            ROUNDS as u64,
            "n={n}: the dense broadcast must be serialized once per round, \
             however many connections fan it out"
        );
        let comm = engine.comm();
        assert_eq!(
            pool.wire_observed(),
            (comm.wire_up, comm.wire_down),
            "n={n}: socket-observed bytes must equal the engine's arithmetic mirror"
        );
        let per = comm.wire_down as f64 / (ROUNDS * n) as f64;
        per_conn_down.push(per);

        let rps = ROUNDS as f64 / mean;
        let rss_mb = rss.map(|kb| kb as f64 / 1024.0);
        println!(
            "{n:<10} {rps:>12.2} {:>18.1} {per:>16.1} {:>10}",
            rps * n as f64,
            rss_mb.map_or("n/a".to_string(), |m| format!("{m:.1}")),
        );
        table.push(Json::obj(vec![
            ("workers", Json::Num(n as f64)),
            ("rounds", Json::Num(ROUNDS as f64)),
            ("rounds_per_sec", Json::Num(rps)),
            ("client_rounds_per_sec", Json::Num(rps * n as f64)),
            ("wire_down_per_conn_round", Json::Num(per)),
            ("rss_kb", rss.map_or(Json::Null, |kb| Json::Num(kb as f64))),
        ]));
    }

    // flat per-connection cost model: the downlink bytes one connection
    // costs per round must not depend on how many neighbors it has
    let first = per_conn_down[0];
    for (&n, &per) in SIZES.iter().zip(&per_conn_down) {
        assert!(
            (per - first).abs() < 0.5,
            "per-connection downlink cost must be flat across scales: \
             n={n} pays {per:.1} B vs {first:.1} B at n={}",
            SIZES[0]
        );
    }
    println!("(per-connection downlink cost asserted flat across all scales)");

    // machine-readable scale table next to the timing results
    let dir = std::path::Path::new("results/bench");
    if std::fs::create_dir_all(dir).is_ok() {
        let j = Json::obj(vec![("scale", Json::Arr(table))]);
        let path = dir.join("connscale_table.json");
        let _ = std::fs::write(&path, j.to_pretty());
        println!("  -> {}", path.display());
    }

    b.save();
    Ok(())
}
