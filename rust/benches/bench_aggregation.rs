//! Aggregation-path cost: g~ = sum of N k-sparse updates, both
//! materializations, at the paper's two scales.

use ragek::bench::Bench;
use ragek::coordinator::aggregator::Aggregate;
use ragek::sparse::SparseVec;
use ragek::util::rng::Rng;

fn updates(n: usize, d: usize, k: usize, seed: u64) -> Vec<SparseVec> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let idx: Vec<u32> = rng.choose_k(d, k).into_iter().map(|x| x as u32).collect();
            let mut val = vec![0.0f32; k];
            rng.fill_gaussian(&mut val, 1.0);
            SparseVec::new(idx, val)
        })
        .collect()
}

fn main() {
    let mut b = Bench::new("aggregation");
    for (tag, d, k, n) in [
        ("mnist d=39760 k=10  n=10", 39760usize, 10usize, 10usize),
        ("cifar d=2.5M  k=100 n=6 ", 2_515_338, 100, 6),
        ("scale d=2.5M  k=100 n=64", 2_515_338, 100, 64),
    ] {
        let ups = updates(n, d, k, 3);
        b.run(&format!("aggregate.push x{n:<3} {tag}"), || {
            let mut agg = Aggregate::new();
            for u in &ups {
                agg.push(u.clone());
            }
            std::hint::black_box(agg.total_entries());
        });
        let mut agg = Aggregate::new();
        for u in &ups {
            agg.push(u.clone());
        }
        b.run_units(&format!("to_dense          {tag}"), Some(d as f64), || {
            std::hint::black_box(agg.to_dense(d, 1.0));
        });
        b.run_units(&format!("to_padded_pairs   {tag}"), Some((n * k) as f64), || {
            std::hint::black_box(agg.to_padded_pairs(n * k, 1.0));
        });
        // coverage-diagnostic union (sorted concat+dedup; formerly a
        // per-call HashSet)
        b.run_units(&format!("updated_indices   {tag}"), Some((n * k) as f64), || {
            std::hint::black_box(agg.updated_indices());
        });
        // allocation-free variant on the per-round delta-ring hot path:
        // the scratch Vec is reused across calls, so steady state is
        // pure sort+dedup with zero allocator traffic
        let mut union_scratch: Vec<u32> = Vec::new();
        b.run_units(&format!("updated_idx_into  {tag}"), Some((n * k) as f64), || {
            agg.updated_indices_into(&mut union_scratch);
            std::hint::black_box(union_scratch.len());
        });
    }
    b.save();
}
