//! Sim/distributed parity: the in-process simulator and the TCP
//! deployment are two transports over the same `RoundEngine` + client
//! phase functions, so the same config + seed must produce **identical**
//! per-round uploaded index sets and bit-identical final global
//! parameters. This is the regression net for the historical drift
//! between `fl::trainer` and `fl::distributed` (e.g. the worker once
//! reset its Adam moments every round).

use ragek::config::{ExperimentConfig, Payload};
use ragek::coordinator::strategies::StrategyKind;
use ragek::fl::distributed::ServeReport;
use ragek::fl::trainer::Trainer;
use ragek::testing::run_distributed_localhost;

fn parity_cfg(strategy: StrategyKind) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::mnist_smoke();
    cfg.strategy = strategy;
    cfg.payload = Payload::Delta; // what the CLI deploys
    cfg.rounds = 4;
    cfg.n_clients = 2;
    cfg.train_n = 200;
    cfg.test_n = 64;
    cfg.eval_every = 0;
    cfg.recluster_every = 2; // exercise reclustering inside the window
    cfg
}

fn run_sim(cfg: &ExperimentConfig) -> (Vec<Vec<Vec<u32>>>, Vec<f32>) {
    let mut t = Trainer::from_config(cfg).unwrap();
    for _ in 0..cfg.rounds {
        t.run_round().unwrap();
    }
    (
        t.engine().uploaded_log().iter().cloned().collect(),
        t.global_params().to_vec(),
    )
}

fn run_tcp(cfg: &ExperimentConfig) -> ServeReport {
    run_distributed_localhost(cfg).unwrap()
}

#[test]
fn ragek_sim_and_tcp_are_identical() {
    let cfg = parity_cfg(StrategyKind::RageK);
    let (sim_log, sim_params) = run_sim(&cfg);
    let report = run_tcp(&cfg);
    assert_eq!(
        report.uploaded_log, sim_log,
        "per-round requested/uploaded indices must match across transports"
    );
    // identical float ops in identical order on both paths -> bit-exact
    assert_eq!(report.final_params, sim_params, "final global params must match exactly");
}

#[test]
fn client_side_strategy_sim_and_tcp_are_identical() {
    // rTop-k selects *client-side* (from the client's own seeded RNG);
    // parity additionally proves the RNG streams line up across
    // deployments
    let cfg = parity_cfg(StrategyKind::RTopK);
    let (sim_log, sim_params) = run_sim(&cfg);
    let report = run_tcp(&cfg);
    assert_eq!(report.uploaded_log, sim_log);
    assert_eq!(report.final_params, sim_params);
}

/// Partial participation: both transports must draw the same cohorts
/// (same scheduler, same seed), skip the same clients, and stay
/// bit-for-bit identical — and the TCP downlink must prove the broadcast
/// was cohort-scoped and encoded once per round.
#[test]
fn partial_participation_sim_and_tcp_are_identical() {
    let mut cfg = parity_cfg(StrategyKind::RageK);
    cfg.n_clients = 4;
    cfg.participation = 0.5; // cohort of 2, default round-robin
    cfg.rounds = 6;
    let m = cfg.cohort_size() as u64;
    assert_eq!(m, 2);
    let (sim_log, sim_params) = run_sim(&cfg);
    let report = run_tcp(&cfg);
    assert_eq!(report.uploaded_log, sim_log, "cohorts/uploads must match across transports");
    assert_eq!(report.final_params, sim_params, "final global params must match exactly");
    // each round exactly the cohort uploaded; everyone else sat out
    for round in &report.uploaded_log {
        assert_eq!(round.len(), cfg.n_clients);
        assert_eq!(round.iter().filter(|u| !u.is_empty()).count(), m as usize);
    }
    // zero-copy, cohort-scoped broadcast: one Model encode per round and
    // downlink bytes scale with m = 2, not n = 4
    assert_eq!(report.model_encodes, cfg.rounds as u64);
    assert_eq!(report.comm.broadcast_down, cfg.rounds as u64 * m * 4 * cfg.d() as u64);
}

/// The age-debt scheduler is deterministic PS state, so it too must agree
/// across transports.
#[test]
fn age_debt_scheduler_sim_and_tcp_are_identical() {
    let mut cfg = parity_cfg(StrategyKind::RageK);
    cfg.n_clients = 4;
    cfg.participation = 0.5;
    cfg.scheduler = ragek::coordinator::scheduler::SchedulerKind::AgeDebt;
    cfg.rounds = 5;
    let (sim_log, sim_params) = run_sim(&cfg);
    let report = run_tcp(&cfg);
    assert_eq!(report.uploaded_log, sim_log);
    assert_eq!(report.final_params, sim_params);
    // age debt rotates participation: over 5 rounds of cohort 2 every
    // client must have been polled at least once
    let mut polled = vec![false; cfg.n_clients];
    for round in &report.uploaded_log {
        for (i, u) in round.iter().enumerate() {
            if !u.is_empty() {
                polled[i] = true;
            }
        }
    }
    assert!(polled.iter().all(|&p| p), "age debt must eventually poll everyone: {polled:?}");
}
