//! Sim/distributed parity: the in-process simulator and the TCP
//! deployment are two transports over the same `RoundEngine` + client
//! phase functions, so the same config + seed must produce **identical**
//! per-round uploaded index sets and bit-identical final global
//! parameters. This is the regression net for the historical drift
//! between `fl::trainer` and `fl::distributed` (e.g. the worker once
//! reset its Adam moments every round).

use ragek::config::{ExperimentConfig, Payload};
use ragek::coordinator::strategies::StrategyKind;
use ragek::fl::distributed::ServeReport;
use ragek::fl::trainer::Trainer;
use ragek::testing::run_distributed_localhost;

fn parity_cfg(strategy: StrategyKind) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::mnist_smoke();
    cfg.strategy = strategy;
    cfg.payload = Payload::Delta; // what the CLI deploys
    cfg.rounds = 4;
    cfg.n_clients = 2;
    cfg.train_n = 200;
    cfg.test_n = 64;
    cfg.eval_every = 0;
    cfg.recluster_every = 2; // exercise reclustering inside the window
    cfg
}

fn run_sim(cfg: &ExperimentConfig) -> (Vec<Vec<Vec<u32>>>, Vec<f32>) {
    let mut t = Trainer::from_config(cfg).unwrap();
    for _ in 0..cfg.rounds {
        t.run_round().unwrap();
    }
    (t.engine().uploaded_log().to_vec(), t.global_params().to_vec())
}

fn run_tcp(cfg: &ExperimentConfig) -> ServeReport {
    run_distributed_localhost(cfg).unwrap()
}

#[test]
fn ragek_sim_and_tcp_are_identical() {
    let cfg = parity_cfg(StrategyKind::RageK);
    let (sim_log, sim_params) = run_sim(&cfg);
    let report = run_tcp(&cfg);
    assert_eq!(
        report.uploaded_log, sim_log,
        "per-round requested/uploaded indices must match across transports"
    );
    // identical float ops in identical order on both paths -> bit-exact
    assert_eq!(report.final_params, sim_params, "final global params must match exactly");
}

#[test]
fn client_side_strategy_sim_and_tcp_are_identical() {
    // rTop-k selects *client-side* (from the client's own seeded RNG);
    // parity additionally proves the RNG streams line up across
    // deployments
    let cfg = parity_cfg(StrategyKind::RTopK);
    let (sim_log, sim_params) = run_sim(&cfg);
    let report = run_tcp(&cfg);
    assert_eq!(report.uploaded_log, sim_log);
    assert_eq!(report.final_params, sim_params);
}
