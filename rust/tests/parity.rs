//! Sim/distributed parity: the in-process simulator and the TCP
//! deployment are two transports over the same `RoundEngine` + client
//! phase functions, so the same config + seed must produce **identical**
//! per-round uploaded index sets and bit-identical final global
//! parameters. This is the regression net for the historical drift
//! between `fl::trainer` and `fl::distributed` (e.g. the worker once
//! reset its Adam moments every round).

use ragek::age::DenseAgeVector;
use ragek::clustering::MergeRule;
use ragek::config::{Downlink, ExperimentConfig, Payload};
use ragek::coordinator::strategies::StrategyKind;
use ragek::coordinator::topology::Topology;
use ragek::fl::codec::Codec;
use ragek::fl::distributed::ServeReport;
use ragek::fl::metrics::CommStats;
use ragek::fl::trainer::Trainer;
use ragek::testing::run_distributed_localhost;

fn parity_cfg(strategy: StrategyKind) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::mnist_smoke();
    cfg.strategy = strategy;
    cfg.payload = Payload::Delta; // what the CLI deploys
    cfg.rounds = 4;
    cfg.n_clients = 2;
    cfg.train_n = 200;
    cfg.test_n = 64;
    cfg.eval_every = 0;
    cfg.recluster_every = 2; // exercise reclustering inside the window
    cfg
}

fn run_sim(cfg: &ExperimentConfig) -> (Vec<Vec<Vec<u32>>>, Vec<f32>) {
    let (log, params, _) = run_sim_comm(cfg);
    (log, params)
}

/// Like [`run_sim`] but also returning the communication accounting;
/// works under every topology (the uploaded log is global-id-indexed in
/// both drivers).
fn run_sim_comm(cfg: &ExperimentConfig) -> (Vec<Vec<Vec<u32>>>, Vec<f32>, CommStats) {
    let mut t = Trainer::from_config(cfg).unwrap();
    for _ in 0..cfg.rounds {
        t.run_round().unwrap();
    }
    (
        t.uploaded_log().iter().cloned().collect(),
        t.global_params().to_vec(),
        t.comm(),
    )
}

fn run_tcp(cfg: &ExperimentConfig) -> ServeReport {
    run_distributed_localhost(cfg).unwrap()
}

#[test]
fn ragek_sim_and_tcp_are_identical() {
    let cfg = parity_cfg(StrategyKind::RageK);
    let (sim_log, sim_params) = run_sim(&cfg);
    let report = run_tcp(&cfg);
    assert_eq!(
        report.uploaded_log, sim_log,
        "per-round requested/uploaded indices must match across transports"
    );
    // identical float ops in identical order on both paths -> bit-exact
    assert_eq!(report.final_params, sim_params, "final global params must match exactly");
}

#[test]
fn client_side_strategy_sim_and_tcp_are_identical() {
    // rTop-k selects *client-side* (from the client's own seeded RNG);
    // parity additionally proves the RNG streams line up across
    // deployments
    let cfg = parity_cfg(StrategyKind::RTopK);
    let (sim_log, sim_params) = run_sim(&cfg);
    let report = run_tcp(&cfg);
    assert_eq!(report.uploaded_log, sim_log);
    assert_eq!(report.final_params, sim_params);
}

/// Partial participation: both transports must draw the same cohorts
/// (same scheduler, same seed), skip the same clients, and stay
/// bit-for-bit identical — and the TCP downlink must prove the broadcast
/// was cohort-scoped and encoded once per round.
#[test]
fn partial_participation_sim_and_tcp_are_identical() {
    let mut cfg = parity_cfg(StrategyKind::RageK);
    cfg.n_clients = 4;
    cfg.participation = 0.5; // cohort of 2, default round-robin
    cfg.rounds = 6;
    let m = cfg.cohort_size() as u64;
    assert_eq!(m, 2);
    let (sim_log, sim_params) = run_sim(&cfg);
    let report = run_tcp(&cfg);
    assert_eq!(report.uploaded_log, sim_log, "cohorts/uploads must match across transports");
    assert_eq!(report.final_params, sim_params, "final global params must match exactly");
    // each round exactly the cohort uploaded; everyone else sat out
    for round in &report.uploaded_log {
        assert_eq!(round.len(), cfg.n_clients);
        assert_eq!(round.iter().filter(|u| !u.is_empty()).count(), m as usize);
    }
    // zero-copy, cohort-scoped broadcast: one Model encode per round and
    // downlink bytes scale with m = 2, not n = 4
    assert_eq!(report.model_encodes, cfg.rounds as u64);
    assert_eq!(report.comm.broadcast_down, cfg.rounds as u64 * m * 4 * cfg.d() as u64);
}

/// The packed v2 codec is lossless: a TCP run negotiating `packed` must
/// be bit-for-bit identical to the raw TCP run *and* the simulator —
/// identical per-round uploaded index sets (decoded indices identical in
/// content and order) and bit-identical final global parameters — while
/// putting strictly fewer bytes on the wire.
#[test]
fn packed_codec_tcp_is_bit_identical_to_raw() {
    let cfg = parity_cfg(StrategyKind::RageK);
    let (sim_log, sim_params) = run_sim(&cfg);
    let raw = run_tcp(&cfg);
    let mut pcfg = cfg.clone();
    pcfg.codec = Codec::Packed;
    let packed = run_tcp(&pcfg);
    assert_eq!(packed.uploaded_log, sim_log, "packed uploads must match the simulator");
    assert_eq!(packed.final_params, sim_params, "packed params must match bit-for-bit");
    assert_eq!(packed.uploaded_log, raw.uploaded_log);
    assert_eq!(packed.final_params, raw.final_params);
    // the engine's arithmetic wire accounting is exact under BOTH codecs:
    // it equals the bytes observed crossing the PS sockets
    for rep in [&raw, &packed] {
        assert_eq!(rep.comm.wire_up, rep.wire_up_observed, "uplink accounting must be exact");
        assert_eq!(rep.comm.wire_down, rep.wire_down_observed, "downlink accounting must be exact");
    }
    // and the packed format strictly shrinks the sparse-frame traffic
    // (the >= 2x pin on the standard scenario lives in bench_end2end)
    assert!(packed.comm.wire_up < raw.comm.wire_up);
    assert!(packed.comm.wire_down < raw.comm.wire_down);
    // §6 protocol counters are codec-independent by design
    assert_eq!(packed.comm.uplink(), raw.comm.uplink());
    assert_eq!(packed.comm.downlink(), raw.comm.downlink());
}

/// `packed-f16` is lossy in the update *values* only: round 1 (identical
/// f32 broadcast in, indices lossless) must select identical uploads, and
/// the whole run must stay close to the lossless one — but the index
/// streams and the protocol flow never diverge structurally.
#[test]
fn packed_f16_stays_close_and_round_one_is_identical() {
    let cfg = parity_cfg(StrategyKind::RageK);
    let (sim_log, sim_params) = run_sim(&cfg);
    let mut fcfg = cfg.clone();
    fcfg.codec = Codec::PackedF16;
    let f16 = run_tcp(&fcfg);
    // round 1: same broadcast, same reports, same age state -> the
    // requested/uploaded index sets are identical; f16 only touches the
    // uploaded values
    assert_eq!(f16.uploaded_log[0], sim_log[0], "round-1 indices must be identical");
    assert_eq!(f16.comm.wire_up, f16.wire_up_observed, "f16 wire accounting must be exact");
    // values drift within f16 tolerance, compounded over 4 smoke rounds:
    // the run must stay finite and near the lossless trajectory
    assert_eq!(f16.final_params.len(), sim_params.len());
    let mut max_diff = 0f32;
    for (a, b) in f16.final_params.iter().zip(&sim_params) {
        assert!(a.is_finite());
        max_diff = max_diff.max((a - b).abs());
    }
    assert!(max_diff < 0.1, "f16 drift too large: {max_diff}");
}

/// Topology pin 1: `Sharded { shards: 1 }` runs the whole sharded code
/// path — root re-broadcast into the shard engine, shard collect, root
/// merge + apply, shard bookkeeping, rolled-up accounting — and must be
/// **bit-for-bit** the flat engine: identical per-round uploaded index
/// sets, identical final global parameters, identical (rolled-up)
/// communication counters.
#[test]
fn flat_and_sharded_one_are_identical() {
    let mut cfg = parity_cfg(StrategyKind::RageK);
    cfg.n_clients = 4;
    cfg.participation = 0.5; // exercise cohorts + absence under sharding
    cfg.rounds = 6;
    let (flat_log, flat_params, flat_comm) = run_sim_comm(&cfg);
    let mut scfg = cfg.clone();
    scfg.topology = Topology::Sharded { shards: 1, root_merge: MergeRule::Min };
    let (sh_log, sh_params, sh_comm) = run_sim_comm(&scfg);
    assert_eq!(sh_log, flat_log, "uploaded index sets must match flat exactly");
    assert_eq!(sh_params, flat_params, "global params must match flat bit-for-bit");
    assert_eq!(sh_comm, flat_comm, "rolled-up accounting must equal the flat counters");
}

/// Topology pin 2: a fixed-seed `shards = 2` run is deterministic across
/// repeats (shard collect phases run on scoped threads — thread
/// interleaving must not leak into results), and the root's lazy
/// shard-merged age vector equals the dense eq.-(2) oracle replayed from
/// the uploaded log.
#[test]
fn sharded_two_is_deterministic_with_exact_age_merge() {
    let mut cfg = parity_cfg(StrategyKind::RageK);
    cfg.n_clients = 4;
    cfg.participation = 0.5;
    cfg.rounds = 6;
    cfg.recluster_every = 0; // singleton clusters: one age vector per client
    cfg.topology = Topology::Sharded { shards: 2, root_merge: MergeRule::Min };

    let run = || {
        let mut t = Trainer::from_config(&cfg).unwrap();
        for _ in 0..cfg.rounds {
            t.run_round().unwrap();
        }
        let log: Vec<Vec<Vec<u32>>> = t.uploaded_log().iter().cloned().collect();
        let merged = t.sharded().expect("sharded driver").merged_ages();
        (log, t.global_params().to_vec(), merged)
    };
    let (log_a, params_a, merged_a) = run();
    let (log_b, params_b, merged_b) = run();
    assert_eq!(log_a, log_b, "sharded runs must be deterministic across repeats");
    assert_eq!(params_a, params_b);
    assert_eq!(merged_a, merged_b);

    // dense oracle: each client is its own cluster (recluster_every = 0),
    // so its eq.-(2) vector replays from its uploaded entries — empty on
    // rounds it sat out, exactly how the PS records absence. The root's
    // merged lazy vector (rebased across divergent shard epochs) must
    // equal the elementwise-min of the dense sweeps.
    let d = cfg.d();
    let mut dense: Vec<DenseAgeVector> =
        (0..cfg.n_clients).map(|_| DenseAgeVector::new(d)).collect();
    for round in &log_a {
        for (client, uploaded) in round.iter().enumerate() {
            dense[client].update(uploaded);
        }
    }
    let mut oracle = dense[0].clone();
    for v in &dense[1..] {
        oracle.merge_min(v);
    }
    assert_eq!(
        merged_a.to_vec(),
        oracle.as_slice(),
        "root-merged lazy ages must equal the dense oracle"
    );
    // sanity: the merge actually saw divergent state (some index aged)
    assert!(merged_a.to_vec().iter().any(|&a| a > 0), "oracle comparison must not be vacuous");
}

/// Topology pin 3: the sharded in-process driver (parallel shard threads)
/// and the sharded TCP deployment (serial shard drive, one PS socket pool
/// per shard, workers joining with shard-local ids) are the same
/// two-level protocol — identical uploads and bit-identical final
/// parameters — and the rolled-up wire accounting still equals the bytes
/// observed on the shard PS sockets.
#[test]
fn sharded_sim_and_tcp_are_identical() {
    let mut cfg = parity_cfg(StrategyKind::RageK);
    cfg.n_clients = 4;
    cfg.rounds = 4;
    cfg.topology = Topology::Sharded { shards: 2, root_merge: MergeRule::Min };
    let (sim_log, sim_params, sim_comm) = run_sim_comm(&cfg);
    let report = run_tcp(&cfg);
    assert_eq!(report.uploaded_log, sim_log, "shard cohorts/uploads must match across transports");
    assert_eq!(report.final_params, sim_params, "root params must match bit-for-bit");
    assert_eq!(report.comm, sim_comm, "rolled-up accounting must agree with the simulator");
    // per-shard wire pins survive the roll-up
    assert_eq!(report.comm.wire_up, report.wire_up_observed);
    assert_eq!(report.comm.wire_down, report.wire_down_observed);
    // one Model encode per shard per round (each shard pool broadcasts
    // its cohort's frame exactly once)
    assert_eq!(report.model_encodes, 2 * cfg.rounds as u64);
}

/// Topology pin 4: root-level reclustering + dynamic re-sharding run the
/// identical deterministic sequence on both transports. With PaperPairs
/// over 6 clients and 2 shards, pair (2,3) straddles the initial
/// contiguous slices — once the fleet-wide DBSCAN finds the pairs, the
/// recluster boundary re-partitions via `ClusterManager::shard_slices`
/// and a worker stream is handed between the shard pools; either way
/// (pairs found or not) the sim and TCP runs must stay bit-for-bit
/// identical, with the rolled-up wire accounting still equal to the
/// observed socket bytes.
#[test]
fn resharding_sharded_sim_and_tcp_are_identical() {
    let mut cfg = parity_cfg(StrategyKind::RageK);
    cfg.n_clients = 6;
    cfg.rounds = 8;
    cfg.recluster_every = 4;
    cfg.topology = Topology::Sharded { shards: 2, root_merge: MergeRule::Min };
    assert!(cfg.reshard, "dynamic re-sharding is on by default");
    let (sim_log, sim_params, sim_comm) = run_sim_comm(&cfg);
    let report = run_tcp(&cfg);
    assert_eq!(report.uploaded_log, sim_log, "uploads must match across the re-shard");
    assert_eq!(report.final_params, sim_params, "params must match bit-for-bit");
    assert_eq!(report.comm, sim_comm);
    assert_eq!(report.comm.wire_up, report.wire_up_observed);
    assert_eq!(report.comm.wire_down, report.wire_down_observed);
    assert_eq!(report.casualties, 0, "a clean run has no casualties");
}

/// The delta downlink is a pure wire representation (DESIGN.md §9):
/// training — uploads, cohorts, final parameters — is bit-for-bit the
/// dense run on BOTH transports, the sim and TCP accounting agree, the
/// arithmetic wire mirror equals the observed socket bytes, and the
/// downlink shrinks by well over the 20x acceptance floor.
#[test]
fn delta_downlink_sim_and_tcp_match_dense_bit_for_bit() {
    let cfg = parity_cfg(StrategyKind::RageK);
    let (sim_log, sim_params, _) = run_sim_comm(&cfg);
    let dense = run_tcp(&cfg);
    let mut dcfg = cfg.clone();
    dcfg.downlink = Downlink::Delta;
    let (delta_sim_log, delta_sim_params, delta_sim_comm) = run_sim_comm(&dcfg);
    assert_eq!(delta_sim_log, sim_log, "sim training must be downlink-independent");
    assert_eq!(delta_sim_params, sim_params);
    let delta = run_tcp(&dcfg);
    assert_eq!(delta.uploaded_log, sim_log, "TCP delta uploads must match the dense sim");
    assert_eq!(delta.final_params, sim_params, "sparse frames must reconstruct exactly");
    assert_eq!(delta.comm, delta_sim_comm, "sim and TCP delta accounting must agree");
    assert_eq!(delta.comm.wire_up, delta.wire_up_observed);
    assert_eq!(
        delta.comm.wire_down, delta.wire_down_observed,
        "the per-member delta arithmetic must equal the observed socket bytes"
    );
    assert_eq!(delta.model_encodes, 0, "a healthy delta run needs no dense frames");
    assert_eq!(delta.casualties, 0);
    assert!(
        delta.comm.wire_down * 20 < dense.comm.wire_down,
        "delta downlink {} must be >= 20x under dense {}",
        delta.comm.wire_down,
        dense.comm.wire_down
    );
    // the uplink and the semantic §6 counters are untouched
    assert_eq!(delta.comm.uplink(), dense.comm.uplink());
    assert_eq!(delta.comm.wire_up, dense.comm.wire_up);
}

/// Partial participation exercises the generation ring: off-cohort
/// clients fall multiple generations behind and their next broadcast
/// accumulates the gap's unions into one delta — still bit-for-bit the
/// dense run, on both transports.
#[test]
fn delta_downlink_partial_participation_parity() {
    let mut cfg = parity_cfg(StrategyKind::RageK);
    cfg.n_clients = 4;
    cfg.participation = 0.5;
    cfg.rounds = 6;
    let (dense_log, dense_params, _) = run_sim_comm(&cfg);
    cfg.downlink = Downlink::Delta;
    let (sim_log, sim_params, sim_comm) = run_sim_comm(&cfg);
    assert_eq!(sim_log, dense_log, "gap-accumulated deltas must not perturb training");
    assert_eq!(sim_params, dense_params);
    let report = run_tcp(&cfg);
    assert_eq!(report.uploaded_log, sim_log);
    assert_eq!(report.final_params, sim_params);
    assert_eq!(report.comm, sim_comm);
    assert_eq!(report.comm.wire_down, report.wire_down_observed);
    assert_eq!(report.model_encodes, 0, "every gap must fit the ring on this run");
}

/// Topology: `Sharded { shards: 1 }` under the delta downlink must stay
/// bit-for-bit the flat engine — the fleet-wide update union is fed to
/// every shard engine, so the rings and plans coincide.
#[test]
fn delta_downlink_flat_and_sharded_one_are_identical() {
    let mut cfg = parity_cfg(StrategyKind::RageK);
    cfg.n_clients = 4;
    cfg.participation = 0.5;
    cfg.rounds = 6;
    cfg.downlink = Downlink::Delta;
    let (flat_log, flat_params, flat_comm) = run_sim_comm(&cfg);
    let mut scfg = cfg.clone();
    scfg.topology = Topology::Sharded { shards: 1, root_merge: MergeRule::Min };
    let (sh_log, sh_params, sh_comm) = run_sim_comm(&scfg);
    assert_eq!(sh_log, flat_log, "sharded(1) delta uploads must match flat exactly");
    assert_eq!(sh_params, flat_params);
    assert_eq!(sh_comm, flat_comm, "delta accounting must roll up identically");
}

/// The delta downlink survives root reclustering + dynamic re-sharding:
/// the acked-generation ledger rides the fleet-record hand-off and the
/// shard engines keep byte-identical plans — sim and TCP agree, and both
/// equal the dense-downlink training trajectory.
#[test]
fn delta_downlink_resharding_sim_and_tcp_are_identical() {
    let mut cfg = parity_cfg(StrategyKind::RageK);
    cfg.n_clients = 6;
    cfg.rounds = 8;
    cfg.recluster_every = 4;
    cfg.topology = Topology::Sharded { shards: 2, root_merge: MergeRule::Min };
    let (dense_log, dense_params, _) = run_sim_comm(&cfg);
    cfg.downlink = Downlink::Delta;
    let (sim_log, sim_params, sim_comm) = run_sim_comm(&cfg);
    assert_eq!(sim_log, dense_log, "the re-shard must not perturb delta training");
    assert_eq!(sim_params, dense_params);
    let report = run_tcp(&cfg);
    assert_eq!(report.uploaded_log, sim_log);
    assert_eq!(report.final_params, sim_params);
    assert_eq!(report.comm, sim_comm);
    assert_eq!(report.comm.wire_up, report.wire_up_observed);
    assert_eq!(report.comm.wire_down, report.wire_down_observed);
    assert_eq!(report.casualties, 0);
}

/// Speculative over-scheduling is off by default: every parity pin in
/// this file runs with `overschedule = 0`, i.e. the scheduler selects
/// exactly `m` members and the quota path is never armed — today's
/// protocol bit-for-bit. This pin keeps that default honest.
#[test]
fn overschedule_defaults_to_off() {
    let cfg = parity_cfg(StrategyKind::RageK);
    assert_eq!(cfg.overschedule, 0);
    assert_eq!(cfg.scheduled_cohort_size(), cfg.cohort_size());
    let mut scfg = cfg.clone();
    scfg.overschedule = 1;
    assert_eq!(scfg.scheduled_cohort_size(), scfg.cohort_size() + 1);
}

/// A speculative sim run (ε > 0) is deterministic across repeats and
/// still commits exactly `m` reports per round — the ε stragglers are
/// cancelled, never uploaded, and the run replays bit-for-bit.
#[test]
fn speculative_sim_is_deterministic_and_commits_m_per_round() {
    let mut cfg = parity_cfg(StrategyKind::RageK);
    cfg.n_clients = 4;
    cfg.participation = 0.5;
    cfg.overschedule = 1; // schedule 3, commit 2
    cfg.rounds = 6;
    let m = cfg.cohort_size();
    let (log_a, params_a) = run_sim(&cfg);
    let (log_b, params_b) = run_sim(&cfg);
    assert_eq!(log_a, log_b, "speculative sim must be deterministic across repeats");
    assert_eq!(params_a, params_b);
    for round in &log_a {
        assert_eq!(
            round.iter().filter(|u| !u.is_empty()).count(),
            m,
            "each speculative round commits exactly m uploads"
        );
    }
}

/// The age-debt scheduler is deterministic PS state, so it too must agree
/// across transports.
#[test]
fn age_debt_scheduler_sim_and_tcp_are_identical() {
    let mut cfg = parity_cfg(StrategyKind::RageK);
    cfg.n_clients = 4;
    cfg.participation = 0.5;
    cfg.scheduler = ragek::coordinator::scheduler::SchedulerKind::AgeDebt;
    cfg.rounds = 5;
    let (sim_log, sim_params) = run_sim(&cfg);
    let report = run_tcp(&cfg);
    assert_eq!(report.uploaded_log, sim_log);
    assert_eq!(report.final_params, sim_params);
    // age debt rotates participation: over 5 rounds of cohort 2 every
    // client must have been polled at least once
    let mut polled = vec![false; cfg.n_clients];
    for round in &report.uploaded_log {
        for (i, u) in round.iter().enumerate() {
            if !u.is_empty() {
                polled[i] = true;
            }
        }
    }
    assert!(polled.iter().all(|&p| p), "age debt must eventually poll everyone: {polled:?}");
}
