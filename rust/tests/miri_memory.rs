//! Memory-model conformance under Miri (DESIGN.md §13).
//!
//! This file is the pinned allowlist for the nightly `cargo miri test`
//! CI job: every test here is **socket-free and clock-free** (Miri has
//! no network and no monotonic clock), exercising exactly the unsafe-
//! adjacent surfaces a remote peer can reach — the codec decoders over
//! attacker-controlled bytes, the resumable transport cursors through
//! pathological 1-byte/`WouldBlock`/`Interrupted` streams, and the
//! `#[repr(C)]` FFI mirror handed to `poll(2)`.
//!
//! Keep it that way: a test that opens a `TcpStream`, spawns the
//! reactor, or reads a clock belongs in the ordinary integration suites,
//! not here — Miri would reject it (or worse, silently skip the
//! interesting part). Case counts are small; Miri runs ~100x slower
//! than native.

use std::io::{Read, Write};

use ragek::fl::codec::{
    f16_bits_to_f32, f32_to_f16_bits, index_block_bytes, varint_len, write_index_block,
    write_varint, Dec, FrameBuf, IndexScratch,
};
use ragek::fl::transport::{parse_frame_header, IoStep, Msg, RecvCursor, SendCursor, MAGIC};
use ragek::fl::Codec;
use ragek::fl::reactor::{PollFd, POLLIN, POLLOUT};
use ragek::sparse::SparseVec;

const ALL: [Codec; 3] = [Codec::Raw, Codec::Packed, Codec::PackedF16];

/// One frame of every wire variant — mirrors the fixture behind the
/// `wire_bytes_never_encodes` pin (the analyze lint keeps that one
/// exhaustive; this one exists so Miri sees every decode path).
fn every_variant() -> Vec<Msg> {
    vec![
        Msg::Join { client_id: 3, codec: Codec::Packed },
        Msg::Rejoin { client_id: 3, generation: 2, held_digest: 1, codec: Codec::Packed },
        Msg::Model { round: 7, params: vec![] },
        Msg::Model { round: 7, params: vec![1.0, -2.5, 3.25] },
        Msg::Delta {
            round: 6,
            base_round: 2,
            digest: 99,
            delta: SparseVec::new(vec![10, 11, 900], vec![0.5, -0.5, 2.0]),
        },
        Msg::Delta { round: 6, base_round: 5, digest: 0, delta: SparseVec::default() },
        Msg::Report {
            client_id: 1,
            round: 2,
            report: SparseVec::new(vec![900, 5], vec![0.5, -0.25]),
            mean_loss: 2.25,
        },
        Msg::Request { round: 9, indices: vec![1, 200_000, 3] },
        Msg::Request { round: 9, indices: vec![] },
        Msg::Update {
            client_id: 0,
            round: 1,
            update: SparseVec::new(vec![4, 8, 15], vec![0.125, 0.25, 0.5]),
        },
        Msg::Shutdown,
        Msg::Sit { round: 4 },
    ]
}

/// encode -> decode -> encode is byte-identical in every codec. (Exact
/// `Msg` equality would be too strong: packed codecs deliberately drop
/// Report values, so the *bytes* are the invariant.)
#[test]
fn msg_encode_decode_encode_is_byte_stable() {
    for codec in ALL {
        for m in every_variant() {
            let frame = m.encode(codec);
            assert_eq!(m.wire_bytes(codec), frame.len(), "{codec:?} {m:?}");
            let back = Msg::decode(&frame[8..], codec)
                .unwrap_or_else(|e| panic!("{codec:?} {m:?}: {e:#}"));
            assert_eq!(back.encode(codec), frame, "{codec:?} {m:?}");
        }
    }
}

#[test]
fn truncated_payloads_error_under_miri() {
    // every strict prefix of a representative payload must Err (never
    // read out of bounds — that is the point of running this under Miri)
    for codec in ALL {
        for m in [
            Msg::Rejoin { client_id: 9, generation: 1, held_digest: 7, codec },
            Msg::Request { round: 3, indices: vec![2, 40, 41, 9000] },
            Msg::Model { round: 1, params: vec![0.5, -0.5] },
        ] {
            let frame = m.encode(codec);
            let payload = &frame[8..];
            for cut in 0..payload.len() {
                assert!(
                    Msg::decode(&payload[..cut], codec).is_err(),
                    "{codec:?} {m:?} cut at {cut} must not decode"
                );
            }
        }
    }
}

#[test]
fn varint_boundaries_roundtrip() {
    for x in [0u32, 1, 127, 128, 16383, 16384, (1 << 28) - 1, 1 << 28, u32::MAX] {
        let mut b = Vec::new();
        write_varint(&mut b, x);
        assert_eq!(b.len(), varint_len(x));
        let mut d = Dec::new(&b);
        assert_eq!(d.varint().unwrap(), x);
        d.done().unwrap();
    }
    // overlong and truncated forms stay errors under Miri's strict rules
    assert!(Dec::new(&[0x80]).varint().is_err());
    assert!(Dec::new(&[0xff, 0xff, 0xff, 0xff, 0x10]).varint().is_err());
}

#[test]
fn f16_conversions_are_total() {
    for x in [0.0f32, -0.0, 1.0, -2.5, 65504.0, 1e-8, f32::INFINITY, f32::NAN] {
        let h = f32_to_f16_bits(x);
        let back = f16_bits_to_f32(h);
        // totality + idempotence, not exactness: f16 is lossy by design
        assert_eq!(f32_to_f16_bits(back), h, "f16 bits must be stable for {x}");
    }
}

#[test]
fn index_block_roundtrips_in_original_order() {
    let mut scratch = IndexScratch::default();
    for idx in [vec![], vec![7], vec![3, 1, 2], vec![1_000_000, 0, 500_000, 2]] {
        let mut b = Vec::new();
        write_index_block(&mut b, &idx, &mut scratch);
        assert_eq!(b.len(), index_block_bytes(&idx));
        let mut d = Dec::new(&b);
        assert_eq!(d.index_block().unwrap(), idx);
        d.done().unwrap();
    }
}

// ------------------------------------------------------------ mock I/O

/// A `Read`/`Write` that moves at most one byte per call and interleaves
/// `WouldBlock` (every other call) plus a single `Interrupted` hiccup —
/// the worst legal behavior of a nonblocking socket.
struct Trickle {
    data: Vec<u8>,
    pos: usize,
    calls: usize,
    interrupted_once: bool,
    sink: Vec<u8>,
}

impl Trickle {
    fn reader(data: Vec<u8>) -> Self {
        Trickle { data, pos: 0, calls: 0, interrupted_once: false, sink: Vec::new() }
    }

    fn writer() -> Self {
        Trickle::reader(Vec::new())
    }

    fn hiccup(&mut self) -> Option<std::io::Error> {
        self.calls += 1;
        if !self.interrupted_once && self.calls == 3 {
            self.interrupted_once = true;
            return Some(std::io::Error::from(std::io::ErrorKind::Interrupted));
        }
        if self.calls % 2 == 0 {
            return Some(std::io::Error::from(std::io::ErrorKind::WouldBlock));
        }
        None
    }
}

impl Read for Trickle {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        if let Some(e) = self.hiccup() {
            return Err(e);
        }
        if self.pos >= self.data.len() || buf.is_empty() {
            return Ok(0);
        }
        buf[0] = self.data[self.pos];
        self.pos += 1;
        Ok(1)
    }
}

impl Write for Trickle {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        if let Some(e) = self.hiccup() {
            return Err(e);
        }
        if buf.is_empty() {
            return Ok(0);
        }
        self.sink.push(buf[0]);
        Ok(1)
    }

    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

#[test]
fn send_cursor_survives_one_byte_writes_with_hiccups() {
    let frame = Msg::Request { round: 5, indices: vec![3, 1, 4, 1_000] }.encode(Codec::Packed);
    let mut w = Trickle::writer();
    let mut cur = SendCursor::new();
    let mut pendings = 0usize;
    loop {
        match cur.advance(&mut w, &frame).unwrap() {
            IoStep::Done => break,
            IoStep::Pending => pendings += 1,
        }
        assert!(pendings < 10_000, "no forward progress");
    }
    assert_eq!(w.sink, frame, "every byte exactly once, in order");
    assert!(pendings > 0, "the trickle writer must have exercised Pending");
}

#[test]
fn recv_cursor_survives_one_byte_reads_with_hiccups() {
    for codec in ALL {
        let msg = Msg::Update {
            client_id: 2,
            round: 9,
            update: SparseVec::new(vec![11, 3, 700], vec![0.5, -1.0, 0.25]),
        };
        let frame = msg.encode(codec);
        let mut r = Trickle::reader(frame.clone());
        let mut cur = RecvCursor::new();
        let mut fb = FrameBuf::new();
        let mut pendings = 0usize;
        loop {
            match cur.advance(&mut r, &mut fb).unwrap() {
                IoStep::Done => break,
                IoStep::Pending => pendings += 1,
            }
            assert!(pendings < 10_000, "no forward progress");
        }
        assert!(pendings > 0, "the trickle reader must have exercised Pending");
        assert_eq!(fb.last_recv_frame_len(), frame.len());
        assert_eq!(fb.recv_payload(), &frame[8..]);
        let back = Msg::decode(fb.recv_payload(), codec).unwrap();
        assert_eq!(back.encode(codec), frame);
    }
}

#[test]
fn recv_cursor_truncated_stream_is_an_error_never_a_hang() {
    let frame = Msg::Sit { round: 1 }.encode(Codec::Raw);
    for cut in 0..frame.len() {
        let mut r = Trickle::reader(frame[..cut].to_vec());
        let mut cur = RecvCursor::new();
        let mut fb = FrameBuf::new();
        let err = loop {
            match cur.advance(&mut r, &mut fb) {
                Ok(IoStep::Done) => panic!("cut at {cut} must not complete"),
                Ok(IoStep::Pending) => continue,
                Err(e) => break e,
            }
        };
        assert!(format!("{err:#}").contains("closed"), "cut at {cut}: {err:#}");
    }
}

#[test]
fn parse_frame_header_rejects_garbage_before_allocating() {
    let good = Msg::Shutdown.encode(Codec::Raw);
    let mut hdr = [0u8; 8];
    hdr.copy_from_slice(&good[..8]);
    assert_eq!(parse_frame_header(&hdr).unwrap(), good.len() - 8);

    let mut bad_magic = hdr;
    bad_magic[0] ^= 0xff;
    assert!(parse_frame_header(&bad_magic).is_err());

    let mut zero_len = [0u8; 8];
    zero_len[..4].copy_from_slice(&MAGIC.to_le_bytes());
    assert!(parse_frame_header(&zero_len).is_err(), "zero-length payload is implausible");

    let mut huge = zero_len;
    huge[4..].copy_from_slice(&u32::MAX.to_le_bytes());
    assert!(parse_frame_header(&huge).is_err(), "4 GiB claim must be rejected, not allocated");
}

// ----------------------------------------------------------- FFI layout

/// `PollFd` is handed to `poll(2)` as `struct pollfd` — its layout is
/// ABI, not convention. Pin size, alignment, and the offset of every
/// field; Miri additionally checks the pointer arithmetic itself.
#[test]
fn pollfd_layout_matches_struct_pollfd_abi() {
    assert_eq!(std::mem::size_of::<PollFd>(), 8);
    assert_eq!(std::mem::align_of::<PollFd>(), 4);
    let p = PollFd::new(3, POLLIN | POLLOUT);
    let base = &p as *const PollFd as usize;
    assert_eq!(&p.fd as *const _ as usize - base, 0, "fd at offset 0");
    assert_eq!(&p.events as *const _ as usize - base, 4, "events at offset 4");
    assert_eq!(&p.revents as *const _ as usize - base, 6, "revents at offset 6");
    assert_eq!(POLLIN, 0x001, "poll(2) ABI constant");
    assert_eq!(POLLOUT, 0x004, "poll(2) ABI constant");
    assert_eq!(p.revents, 0, "interest entries start with revents cleared");
}
