//! TCP transport integration: a miniature PS <-> clients exchange over
//! real sockets running one full rAge-k protocol round with the actual
//! frame encoding — under the raw v1 codec and the packed v2 codec.

// These tests assert real-time transport behavior (timeouts firing,
// stragglers dying on the clock), so the clippy.toml clock ban
// (DESIGN.md §13) does not apply here.
#![allow(clippy::disallowed_methods)]

use ragek::fl::codec::Codec;
use ragek::fl::transport::{recv, send, Msg};
use ragek::sparse::SparseVec;
use std::net::{TcpListener, TcpStream};
use std::thread;

fn one_protocol_round(codec: Codec) {
    let n_clients = 3usize;
    let d = 64usize;
    let k = 2usize;
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();

    // ---- PS thread
    let ps = thread::spawn(move || -> Vec<SparseVec> {
        let mut streams: Vec<TcpStream> = Vec::new();
        for _ in 0..n_clients {
            let (mut s, _) = listener.accept().unwrap();
            match recv(&mut s, codec).unwrap() {
                Msg::Join { client_id, codec: joined } => {
                    assert!((client_id as usize) < n_clients);
                    assert_eq!(joined, codec, "workers advertise the negotiated codec");
                }
                other => panic!("expected Join, got {other:?}"),
            }
            streams.push(s);
        }
        // broadcast model
        let params = vec![0.5f32; d];
        for s in streams.iter_mut() {
            send(s, &Msg::Model { round: 1, params: params.clone() }, codec).unwrap();
        }
        // collect reports, answer with requests (oldest-k := first k here)
        let mut updates = Vec::new();
        for s in streams.iter_mut() {
            let report = match recv(s, codec).unwrap() {
                Msg::Report { report, round: 1, .. } => report,
                other => panic!("expected Report, got {other:?}"),
            };
            let indices: Vec<u32> = report.idx[..k].to_vec();
            send(s, &Msg::Request { round: 1, indices }, codec).unwrap();
            match recv(s, codec).unwrap() {
                Msg::Update { update, round: 1, .. } => updates.push(update),
                other => panic!("expected Update, got {other:?}"),
            }
        }
        for s in streams.iter_mut() {
            send(s, &Msg::Shutdown, codec).unwrap();
        }
        updates
    });

    // ---- client threads
    let mut handles = Vec::new();
    for id in 0..n_clients {
        handles.push(thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            send(&mut s, &Msg::Join { client_id: id as u32, codec }, codec).unwrap();
            let params = match recv(&mut s, codec).unwrap() {
                Msg::Model { params, round: 1 } => params,
                other => panic!("expected Model, got {other:?}"),
            };
            assert_eq!(params.len(), d);
            // fake a gradient report: indices 10*id..
            let idx: Vec<u32> = (0..4u32).map(|j| (10 * id as u32) + j).collect();
            let val: Vec<f32> = idx.iter().map(|&j| j as f32 * 0.1).collect();
            let report = SparseVec::new(idx, val);
            send(
                &mut s,
                &Msg::Report { client_id: id as u32, round: 1, report: report.clone(), mean_loss: 1.0 },
                codec,
            )
            .unwrap();
            let requested = match recv(&mut s, codec).unwrap() {
                Msg::Request { indices, round: 1 } => indices,
                other => panic!("expected Request, got {other:?}"),
            };
            // answer with values from the report
            let update = ragek::fl::client::Client::answer_request(&report, &requested);
            send(&mut s, &Msg::Update { client_id: id as u32, round: 1, update }, codec).unwrap();
            match recv(&mut s, codec).unwrap() {
                Msg::Shutdown => {}
                other => panic!("expected Shutdown, got {other:?}"),
            }
        }));
    }

    let updates = ps.join().unwrap();
    for h in handles {
        h.join().unwrap();
    }
    // PS got one k-sparse update per client with the client's own indices
    assert_eq!(updates.len(), n_clients);
    let mut firsts: Vec<u32> = updates.iter().map(|u| u.idx[0]).collect();
    firsts.sort_unstable();
    assert_eq!(firsts, vec![0, 10, 20]);
    assert!(updates.iter().all(|u| u.len() == 2));
}

#[test]
fn one_protocol_round_over_tcp_raw() {
    one_protocol_round(Codec::Raw);
}

#[test]
fn one_protocol_round_over_tcp_packed() {
    one_protocol_round(Codec::Packed);
}

/// A bad/duplicate Join must not leave already-accepted workers hung:
/// the PS sends them (and the offender) Shutdown before bailing.
#[test]
fn accept_shuts_down_joined_workers_on_bad_join() {
    use ragek::config::ExperimentConfig;
    use ragek::fl::distributed::TcpClientPool;
    let mut cfg = ExperimentConfig::mnist_smoke();
    cfg.n_clients = 2;
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let accept = thread::spawn(move || TcpClientPool::accept(&cfg, listener));

    // worker 0 joins correctly...
    let mut good = TcpStream::connect(addr).unwrap();
    good.set_read_timeout(Some(std::time::Duration::from_secs(30))).unwrap();
    send(&mut good, &Msg::Join { client_id: 0, codec: Codec::Raw }, Codec::Raw).unwrap();
    // ...then a second connection claims the same id (loopback accept
    // order is connection order, so the good join lands first)
    let mut bad = TcpStream::connect(addr).unwrap();
    bad.set_read_timeout(Some(std::time::Duration::from_secs(30))).unwrap();
    send(&mut bad, &Msg::Join { client_id: 0, codec: Codec::Raw }, Codec::Raw).unwrap();

    let err = accept.join().unwrap();
    assert!(err.is_err(), "duplicate join must fail the accept loop");
    // the already-joined worker was released, not left hanging
    assert_eq!(recv(&mut good, Codec::Raw).unwrap(), Msg::Shutdown);
    // and the offender heard the same
    assert_eq!(recv(&mut bad, Codec::Raw).unwrap(), Msg::Shutdown);
}

/// Codec negotiation: a worker joining with a different wire codec than
/// the PS is configured for must be rejected (and every already-joined
/// worker released), not left speaking an incompatible format.
#[test]
fn accept_rejects_codec_mismatch() {
    use ragek::config::ExperimentConfig;
    use ragek::fl::distributed::TcpClientPool;
    let mut cfg = ExperimentConfig::mnist_smoke();
    cfg.n_clients = 2;
    assert_eq!(cfg.codec, Codec::Raw, "preset default");
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let accept = thread::spawn(move || TcpClientPool::accept(&cfg, listener));

    let mut good = TcpStream::connect(addr).unwrap();
    good.set_read_timeout(Some(std::time::Duration::from_secs(30))).unwrap();
    send(&mut good, &Msg::Join { client_id: 0, codec: Codec::Raw }, Codec::Raw).unwrap();
    // worker 1 was (mis)configured for the packed codec
    let mut bad = TcpStream::connect(addr).unwrap();
    bad.set_read_timeout(Some(std::time::Duration::from_secs(30))).unwrap();
    send(&mut bad, &Msg::Join { client_id: 1, codec: Codec::Packed }, Codec::Raw).unwrap();

    let err = accept.join().unwrap();
    assert!(err.is_err(), "codec mismatch must fail the accept loop");
    assert!(format!("{:#}", err.err().unwrap()).contains("codec"));
    assert_eq!(recv(&mut good, Codec::Raw).unwrap(), Msg::Shutdown);
    assert_eq!(recv(&mut bad, Codec::Raw).unwrap(), Msg::Shutdown);
}

/// A worker that joins and then hangs forever (never reports). With
/// `io_timeout_ms` set, the PS-side read deadline turns it into a
/// per-round **casualty**: the round (and the whole run) finishes with
/// the survivors instead of aborting — the fleet-membership tentpole at
/// the server-loop level.
#[test]
fn stalling_worker_no_longer_aborts_training() {
    use ragek::config::{ExperimentConfig, Payload};
    use ragek::fl::distributed::{run_server_on, run_worker};
    let mut cfg = ExperimentConfig::mnist_smoke();
    cfg.n_clients = 2;
    cfg.payload = Payload::Delta;
    cfg.rounds = 2;
    cfg.train_n = 200;
    cfg.test_n = 64;
    cfg.eval_every = 0;
    cfg.io_timeout_ms = 2000; // >> one local round, << forever

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server_cfg = cfg.clone();
    let t0 = std::time::Instant::now();
    let server = thread::spawn(move || run_server_on(&server_cfg, listener));

    // worker 0 is a real, healthy worker
    let wcfg = cfg.clone();
    let worker = thread::spawn(move || run_worker(&wcfg, &format!("127.0.0.1:{}", addr.port()), 0));
    // "worker" 1 joins, swallows frames, and never answers
    let staller = thread::spawn(move || {
        let mut s = TcpStream::connect(addr).unwrap();
        send(&mut s, &Msg::Join { client_id: 1, codec: Codec::Raw }, Codec::Raw).unwrap();
        while recv(&mut s, Codec::Raw).is_ok() {}
    });

    let report = server.join().unwrap().expect("a hung worker must not abort the run");
    assert_eq!(report.rounds, cfg.rounds);
    assert!(report.casualties >= 1, "the staller must be reported as a casualty");
    // every round completed with the survivor; the staller uploaded
    // nothing (its cluster ages kept growing per eq. 2)
    for round in &report.uploaded_log {
        assert!(!round[0].is_empty(), "the healthy worker keeps contributing");
        assert!(round[1].is_empty(), "the staller contributes nothing");
    }
    assert!(
        t0.elapsed() < std::time::Duration::from_secs(60),
        "casualty detection must be bounded by io_timeout_ms, not a hang"
    );
    // the healthy worker got a clean Shutdown; the staller's stream is
    // closed when the pool drops — either way both must terminate
    let _ = worker.join().unwrap();
    staller.join().unwrap();
}

/// Engine-level view of the same failure: the round returns a survivor
/// cohort + casualty list, the pool reports the stream unreachable, and
/// the engine's fleet walks the client Active -> Suspect -> Dead.
#[test]
fn dead_stream_degrades_fleet_and_round_survives() {
    use ragek::config::{ExperimentConfig, Payload};
    use ragek::coordinator::engine::{ClientPool, RoundEngine};
    use ragek::coordinator::fleet::Membership;
    use ragek::fl::distributed::{run_worker, TcpClientPool};
    let mut cfg = ExperimentConfig::mnist_smoke();
    cfg.n_clients = 2;
    cfg.payload = Payload::Delta;
    cfg.rounds = 1;
    cfg.train_n = 200;
    cfg.test_n = 64;
    cfg.eval_every = 0;
    cfg.io_timeout_ms = 2000;

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let wcfg = cfg.clone();
    let worker = thread::spawn(move || run_worker(&wcfg, &format!("127.0.0.1:{}", addr.port()), 0));
    let staller = thread::spawn(move || {
        let mut s = TcpStream::connect(addr).unwrap();
        send(&mut s, &Msg::Join { client_id: 1, codec: Codec::Raw }, Codec::Raw).unwrap();
        while recv(&mut s, Codec::Raw).is_ok() {}
    });

    let mut pool = TcpClientPool::accept(&cfg, listener).unwrap();
    assert_eq!(pool.health(), vec![true, true], "all streams healthy after accept");
    let init = {
        use ragek::backend::Backend;
        pool.backend().init_params().unwrap()
    };
    let mut engine = RoundEngine::new(&cfg, init);
    let out = engine.run_round(&mut pool).expect("the round must survive the dead stream");
    assert_eq!(out.cohort, vec![0], "the survivor completed the round");
    assert_eq!(out.casualties, vec![1]);
    assert_eq!(
        pool.health(),
        vec![true, false],
        "the timed-out stream must be flagged dead, the healthy one not"
    );
    assert_eq!(engine.fleet().state(1), Membership::Suspect, "first failure: suspect");
    // the next round sees the dead transport and writes the client off
    let out = engine.run_round(&mut pool).unwrap();
    assert_eq!(out.casualties, vec![1]);
    assert_eq!(engine.fleet().state(1), Membership::Dead);
    drop(pool); // closes both streams, releasing the threads
    let _ = worker.join().unwrap();
    staller.join().unwrap();
}

#[test]
fn oversized_frame_rejected() {
    // a frame claiming a 1 GiB payload must be rejected before allocation
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let t = thread::spawn(move || {
        let (mut s, _) = listener.accept().unwrap();
        use std::io::Write;
        let mut frame = Vec::new();
        frame.extend_from_slice(&ragek::fl::transport::MAGIC.to_le_bytes());
        frame.extend_from_slice(&(1u32 << 30).to_le_bytes());
        frame.push(1);
        s.write_all(&frame).unwrap();
    });
    let mut s = TcpStream::connect(addr).unwrap();
    assert!(recv(&mut s, Codec::Raw).is_err());
    t.join().unwrap();
}
