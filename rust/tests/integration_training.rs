//! End-to-end training integration: Algorithm 1 over the full stack
//! (synthetic non-iid data -> clients -> PS -> aggregation -> server
//! optimizer) on the artifact-free Rust backend.

use ragek::config::ExperimentConfig;
use ragek::coordinator::strategies::StrategyKind;
use ragek::fl::trainer::Trainer;

fn smoke(strategy: StrategyKind, rounds: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::mnist_smoke();
    cfg.strategy = strategy;
    cfg.rounds = rounds;
    cfg
}

#[test]
fn ragek_converges_on_noniid_mnist() {
    let mut cfg = smoke(StrategyKind::RageK, 40);
    cfg.eval_every = 10;
    let mut t = Trainer::from_config(&cfg).unwrap();
    let report = t.run().unwrap();
    let first = report.history.rounds.first().unwrap().train_loss;
    let last = report.history.rounds.last().unwrap().train_loss;
    // global-model improvement shows up in the client-side train loss
    // slowly (clients resync to global each round; only k=8 coords flow
    // up per client per round at smoke scale)
    assert!(last < first * 0.95, "train loss: {first} -> {last}");
    // global-model accuracy well above the 10% chance level at smoke scale
    assert!(
        report.final_accuracy > 0.35,
        "global accuracy too low: {}",
        report.final_accuracy
    );
}

#[test]
fn every_strategy_trains_without_error() {
    for strategy in [
        StrategyKind::RageK,
        StrategyKind::RageKIndependent,
        StrategyKind::RTopK,
        StrategyKind::TopK,
        StrategyKind::RandK,
    ] {
        let cfg = smoke(strategy, 6);
        let mut t = Trainer::from_config(&cfg).unwrap();
        let report = t.run().unwrap();
        assert_eq!(report.history.rounds.len(), 6, "{strategy:?}");
        assert!(report.history.rounds.iter().all(|r| r.train_loss.is_finite()));
    }
}

#[test]
fn dense_strategy_uploads_full_gradient() {
    let mut cfg = smoke(StrategyKind::Dense, 3);
    cfg.eval_every = 0;
    let mut t = Trainer::from_config(&cfg).unwrap();
    let report = t.run().unwrap();
    // uplink = rounds * n_clients * 8 bytes * d (sparse-pair encoding of
    // all d coords)
    let expect = 3 * cfg.n_clients as u64 * 8 * cfg.d() as u64;
    assert_eq!(report.history.comm.update_up, expect);
}

#[test]
fn comm_accounting_matches_design_formulas() {
    let rounds = 5usize;
    let cfg = smoke(StrategyKind::RageK, rounds);
    let mut t = Trainer::from_config(&cfg).unwrap();
    let report = t.run().unwrap();
    let (n, r, k, d) = (
        cfg.n_clients as u64,
        cfg.r as u64,
        cfg.k as u64,
        cfg.d() as u64,
    );
    let rounds = rounds as u64;
    let comm = report.history.comm;
    assert_eq!(comm.report_up, rounds * n * 4 * r);
    assert_eq!(comm.update_up, rounds * n * 8 * k);
    assert_eq!(comm.request_down, rounds * n * 4 * k);
    assert_eq!(comm.broadcast_down, rounds * n * 4 * d);

    // rTop-k at the same (r, k): no report, no request
    let cfg2 = smoke(StrategyKind::RTopK, 5);
    let mut t2 = Trainer::from_config(&cfg2).unwrap();
    let report2 = t2.run().unwrap();
    assert_eq!(report2.history.comm.report_up, 0);
    assert_eq!(report2.history.comm.request_down, 0);
    assert_eq!(report2.history.comm.update_up, rounds * n * 8 * k);
}

#[test]
fn training_is_deterministic_in_seed() {
    let run = |seed: u64| {
        let mut cfg = smoke(StrategyKind::RageK, 6);
        cfg.seed = seed;
        cfg.eval_every = 3;
        let mut t = Trainer::from_config(&cfg).unwrap();
        let r = t.run().unwrap();
        (
            r.history.rounds.iter().map(|x| x.train_loss).collect::<Vec<_>>(),
            r.final_accuracy,
        )
    };
    let a = run(7);
    let b = run(7);
    assert_eq!(a, b, "same seed must reproduce exactly");
    let c = run(8);
    assert_ne!(a.0, c.0, "different seed must differ");
}

#[test]
fn ragek_beats_rtopk_on_noniid_split() {
    // the paper's headline claim (Fig. 3), at smoke scale with a fixed
    // budget: rAge-k's clustered coordination should reach at least
    // rTop-k's accuracy (ties allowed at this tiny scale)
    let mut accs = Vec::new();
    for strategy in [StrategyKind::RageK, StrategyKind::RTopK] {
        let mut cfg = smoke(strategy, 30);
        cfg.eval_every = 30;
        let mut t = Trainer::from_config(&cfg).unwrap();
        accs.push(t.run().unwrap().final_accuracy);
    }
    assert!(
        accs[0] >= accs[1] - 0.05,
        "rAge-k {:.3} should not trail rTop-k {:.3} materially",
        accs[0],
        accs[1]
    );
}

#[test]
fn sgd_server_opt_works() {
    let mut cfg = smoke(StrategyKind::RageK, 6);
    cfg.server_opt = "sgd".into();
    cfg.lr_server = 0.05;
    let mut t = Trainer::from_config(&cfg).unwrap();
    let report = t.run().unwrap();
    assert!(report.history.rounds.iter().all(|r| r.train_loss.is_finite()));
}

#[test]
fn dirichlet_and_iid_partitions_train() {
    use ragek::data::partition::Scheme;
    for scheme in [Scheme::Iid, Scheme::Dirichlet { alpha: 0.5 }] {
        let mut cfg = smoke(StrategyKind::RageK, 4);
        cfg.partition = scheme;
        let mut t = Trainer::from_config(&cfg).unwrap();
        let report = t.run().unwrap();
        assert_eq!(report.history.rounds.len(), 4);
        assert!(report.truth_labels.is_none());
    }
}
