//! Data-race conformance under ThreadSanitizer (DESIGN.md §13).
//!
//! This file is the pinned allowlist for the nightly TSan CI job: each
//! test drives one of the two scoped-thread fan-outs in the stack —
//! the [`ragek::fl::InProcessPool`] parallel client lanes and the
//! sharded-engine round threads — end to end, so TSan observes every
//! cross-thread edge (lane partitioning, shard aggregation, the
//! age-vector merges at the root) under a real training workload.
//!
//! The tests are ordinary `cargo test` tests too (they assert real
//! convergence facts, cheaply); the sanitizer is what makes them bite.
//! Keep them socket-free: multi-process transport has its own suites,
//! and TSan only sees races inside one process.

use ragek::clustering::MergeRule;
use ragek::config::ExperimentConfig;
use ragek::coordinator::topology::Topology;
use ragek::fl::trainer::Trainer;

fn smoke(parallel: usize, rounds: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::mnist_smoke();
    cfg.parallel = parallel;
    cfg.rounds = rounds;
    cfg.eval_every = 0;
    cfg
}

/// Every client lane trains concurrently on its own scoped thread; the
/// aggregate must come out finite and the round count exact.
#[test]
fn parallel_lanes_are_race_free() {
    let mut t = Trainer::from_config(&smoke(4, 3)).unwrap();
    let report = t.run().unwrap();
    assert_eq!(report.history.rounds.len(), 3);
    assert!(report.history.rounds.iter().all(|r| r.train_loss.is_finite()));
}

/// Lane partitioning must not change the math: one lane and four lanes
/// over the same seed produce the identical loss trajectory. (Under
/// TSan this doubles as the cross-thread determinism witness — a racy
/// reduction would diverge here long before it segfaults anywhere.)
#[test]
fn lane_count_does_not_change_the_trajectory() {
    let serial = Trainer::from_config(&smoke(1, 2)).unwrap().run().unwrap();
    let fanned = Trainer::from_config(&smoke(4, 2)).unwrap().run().unwrap();
    let a: Vec<f32> = serial.history.rounds.iter().map(|r| r.train_loss).collect();
    let b: Vec<f32> = fanned.history.rounds.iter().map(|r| r.train_loss).collect();
    assert_eq!(a, b, "lane fan-out changed the training trajectory");
}

/// Shard engines run their rounds on scoped threads and merge age
/// vectors at the root; with lanes enabled inside each shard this nests
/// both fan-outs.
#[test]
fn sharded_round_threads_are_race_free() {
    let mut cfg = smoke(2, 3);
    cfg.topology = Topology::Sharded { shards: 2, root_merge: MergeRule::Min };
    let mut t = Trainer::from_config(&cfg).unwrap();
    let report = t.run().unwrap();
    assert_eq!(report.history.rounds.len(), 3);
    assert!(report.history.rounds.iter().all(|r| r.train_loss.is_finite()));
}
