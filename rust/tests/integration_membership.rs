//! Fleet-membership integration: rounds survive drops, absent clients'
//! eq.-(2) ages keep growing against the dense oracle, recovered workers
//! re-admit themselves through the `Rejoin` handshake, and recluster
//! boundaries re-partition the fleet across shard pools (DESIGN.md §8).

use ragek::age::DenseAgeVector;
use ragek::backend::{Backend, RustBackend};
use ragek::clustering::MergeRule;
use ragek::config::{ExperimentConfig, Payload};
use ragek::coordinator::engine::{ClientPool, ClientReport, RoundEngine};
use ragek::coordinator::fleet::Membership;
use ragek::coordinator::topology::{Reshard, ShardedEngine, Topology};
use ragek::fl::codec::Codec;
use ragek::fl::transport::{recv, send, Msg};
use ragek::sparse::SparseVec;
use ragek::testing::{prop_check, FlakyPool};
use std::net::{TcpListener, TcpStream};
use std::thread;

fn chaos_cfg(n: usize, rounds: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::mnist_smoke();
    cfg.n_clients = n;
    cfg.payload = Payload::Delta;
    cfg.rounds = rounds;
    cfg.train_n = 200;
    cfg.test_n = 64;
    cfg.eval_every = 0;
    cfg.recluster_every = 0; // singleton clusters: per-client age oracle
    cfg
}

/// One chaos run: per-round uploaded logs, final params, per-client ages,
/// total casualties, and the per-client rejoin generations.
#[allow(clippy::type_complexity)]
fn run_chaos(
    cfg: &ExperimentConfig,
    drop_rate: f32,
    rejoin_after: usize,
    chaos_seed: u64,
) -> (Vec<Vec<Vec<u32>>>, Vec<f32>, Vec<Vec<u32>>, usize, Vec<u32>) {
    let (mut pool, init) = FlakyPool::new(cfg, drop_rate, rejoin_after, chaos_seed).unwrap();
    let mut engine = RoundEngine::new(cfg, init);
    let mut casualties = 0;
    for _ in 0..cfg.rounds {
        let out = engine.run_round(&mut pool).unwrap();
        casualties += out.casualties.len();
    }
    let log: Vec<Vec<Vec<u32>>> = engine.uploaded_log().iter().cloned().collect();
    let ages: Vec<Vec<u32>> = (0..cfg.n_clients)
        .map(|i| engine.ps().clusters().age_of_client(i).to_vec())
        .collect();
    let generations: Vec<u32> =
        (0..cfg.n_clients).map(|i| engine.fleet().generation(i)).collect();
    (log, engine.global_params().to_vec(), ages, casualties, generations)
}

/// Membership chaos is deterministic: the same seed drops and rejoins
/// the same clients at the same rounds, producing bit-identical final
/// parameters and uploaded logs — and the chaos actually bites (some
/// casualties, some rejoins).
#[test]
fn chaos_run_is_deterministic() {
    let cfg = chaos_cfg(4, 10);
    let a = run_chaos(&cfg, 0.25, 2, 7);
    let b = run_chaos(&cfg, 0.25, 2, 7);
    assert_eq!(a.0, b.0, "uploaded logs must be identical across repeats");
    assert_eq!(a.1, b.1, "final params must be identical across repeats");
    assert_eq!(a.2, b.2, "ages must be identical across repeats");
    assert!(a.3 > 0, "the chaos plan must actually drop someone");
    assert!(
        a.4.iter().any(|&g| g >= 1),
        "at least one client must have rejoined: {:?}",
        a.4
    );
    // with the chaos disabled, the fleet never degrades
    let clean = run_chaos(&cfg, 0.0, 2, 7);
    assert_eq!(clean.3, 0, "zero drop rate must produce zero casualties");
    assert!(clean.4.iter().all(|&g| g == 0), "nobody rejoins on a healthy fleet");
}

/// Property: however the chaos plays out, every client's eq.-(2) age
/// vector equals the [`DenseAgeVector`] oracle replayed from the
/// uploaded log — a dropped round is an empty record, i.e. pure uniform
/// aging (monotone growth), never a reset.
#[test]
fn chaos_ages_match_dense_oracle() {
    let mut cfg = chaos_cfg(4, 5);
    cfg.r = 16;
    cfg.k = 4;
    prop_check("chaos-age-oracle", 4, |g| {
        let chaos_seed = 0x5EED + g.case as u64;
        let drop_rate = 0.1 + 0.1 * (g.case as f32);
        let (log, _, ages, _, _) = run_chaos(&cfg, drop_rate, 1 + g.case % 3, chaos_seed);
        let d = cfg.d();
        let mut dense: Vec<DenseAgeVector> =
            (0..cfg.n_clients).map(|_| DenseAgeVector::new(d)).collect();
        for (round, per_client) in log.iter().enumerate() {
            for (i, uploaded) in per_client.iter().enumerate() {
                let before_max = dense[i].max_age();
                dense[i].update(uploaded);
                if uploaded.is_empty() && dense[i].max_age() != before_max + 1 {
                    return Err(format!(
                        "round {round}: absent client {i} must age uniformly by +1"
                    ));
                }
            }
        }
        for (i, dense_i) in dense.iter().enumerate() {
            if ages[i] != dense_i.as_slice() {
                return Err(format!("client {i}: lazy ages diverged from the dense oracle"));
            }
        }
        Ok(())
    });
}

/// The delta downlink under fixed-seed membership chaos: drops, rejoins
/// and the acked-generation ledger's forget/readmit transitions must not
/// perturb training — uploaded logs, final params and ages are
/// bit-for-bit the dense-downlink chaos run. (The sim pool also digest-
/// checks every broadcast plan against the model actually broadcast, so
/// a stale plan would fail loudly here, mid-chaos.)
#[test]
fn chaos_delta_downlink_matches_dense_bit_for_bit() {
    let cfg = chaos_cfg(4, 10);
    let dense = run_chaos(&cfg, 0.25, 2, 7);
    let mut dcfg = cfg.clone();
    dcfg.downlink = ragek::config::Downlink::Delta;
    let delta = run_chaos(&dcfg, 0.25, 2, 7);
    assert_eq!(delta.0, dense.0, "chaos uploads must be downlink-independent");
    assert_eq!(delta.1, dense.1, "chaos params must be downlink-independent");
    assert_eq!(delta.2, dense.2, "chaos ages must be downlink-independent");
    assert!(delta.3 > 0, "the chaos must actually bite for this pin to mean anything");
    assert!(delta.4.iter().any(|&g| g >= 1), "someone must have rejoined");
}

/// A fully-dead fleet stalls without corrupting state: rounds keep
/// committing (ages grow), and once everyone rejoins training resumes.
#[test]
fn total_outage_recovers_after_rejoin() {
    let cfg = chaos_cfg(2, 8);
    // drop rate 1.0: both clients die at round 1, rejoin 2 rounds later,
    // immediately die again, and so on
    let (log, params, _, casualties, generations) = run_chaos(&cfg, 1.0, 2, 3);
    assert_eq!(log.len(), 8, "every round commits");
    assert!(casualties >= 4);
    assert!(generations.iter().all(|&g| g >= 1), "everyone rejoined at least once");
    assert!(params.iter().all(|p| p.is_finite()));
}

// ================================================== speculative chaos

/// One tuned chaos run (stall / handshake-stall knobs applied before the
/// first round).
struct ChaosRun {
    log: Vec<Vec<Vec<u32>>>,
    params: Vec<f32>,
    ages: Vec<Vec<u32>>,
    casualties: usize,
    cancelled: usize,
    generations: Vec<u32>,
    handshake_stalls: usize,
}

fn run_chaos_tuned(
    cfg: &ExperimentConfig,
    drop_rate: f32,
    rejoin_after: usize,
    chaos_seed: u64,
    tune: impl FnOnce(&mut FlakyPool),
) -> ChaosRun {
    let (mut pool, init) = FlakyPool::new(cfg, drop_rate, rejoin_after, chaos_seed).unwrap();
    tune(&mut pool);
    let mut engine = RoundEngine::new(cfg, init);
    let (mut casualties, mut cancelled) = (0, 0);
    for _ in 0..cfg.rounds {
        let out = engine.run_round(&mut pool).unwrap();
        casualties += out.casualties.len();
        cancelled += out.cancelled.len();
    }
    ChaosRun {
        log: engine.uploaded_log().iter().cloned().collect(),
        params: engine.global_params().to_vec(),
        ages: (0..cfg.n_clients)
            .map(|i| engine.ps().clusters().age_of_client(i).to_vec())
            .collect(),
        casualties,
        cancelled,
        generations: (0..cfg.n_clients).map(|i| engine.fleet().generation(i)).collect(),
        handshake_stalls: pool.n_handshake_stalls(),
    }
}

/// Speculative rounds under stall chaos (slow clients, nobody dead):
/// every round commits at most `m` reports — exactly `m` whenever enough
/// fast members remain — the stragglers are cancelled (never casualties
/// while the quota is satisfiable), the run replays deterministically,
/// and the eq.-(2) ages still equal the dense oracle: a cancelled round
/// is an empty upload record, pure uniform aging.
#[test]
fn speculative_chaos_commits_m_with_dense_oracle_ages() {
    let mut cfg = chaos_cfg(6, 10);
    cfg.participation = 0.5; // m = 3
    cfg.overschedule = 2; // schedule 5, commit on the first 3
    let m = cfg.cohort_size();
    let run = || {
        run_chaos_tuned(&cfg, 0.0, 2, 11, |pool| pool.set_stall_rate(0.3))
    };
    let a = run();
    let b = run();
    assert_eq!(a.log, b.log, "speculative stall chaos must be deterministic");
    assert_eq!(a.params, b.params);
    assert_eq!(a.ages, b.ages);
    assert!(a.cancelled > 0, "the stall chaos must actually cancel someone");
    for (round, per_client) in a.log.iter().enumerate() {
        let committed = per_client.iter().filter(|u| !u.is_empty()).count();
        assert!(
            committed <= m,
            "round {}: {committed} commits exceed the quota m = {m}",
            round + 1
        );
    }
    assert!(
        a.log.iter().any(|r| r.iter().filter(|u| !u.is_empty()).count() == m),
        "some round must have filled its quota"
    );
    assert!(a.params.iter().all(|p| p.is_finite()));
    // dense eq.-(2) oracle over the full log: cancellation is recorded
    // as absence, so every client's lazy ages replay exactly
    let d = cfg.d();
    let mut dense: Vec<DenseAgeVector> =
        (0..cfg.n_clients).map(|_| DenseAgeVector::new(d)).collect();
    for per_client in &a.log {
        for (i, uploaded) in per_client.iter().enumerate() {
            dense[i].update(uploaded);
        }
    }
    for (i, dense_i) in dense.iter().enumerate() {
        assert_eq!(
            a.ages[i],
            dense_i.as_slice(),
            "client {i}: lazy ages diverged from the dense oracle under cancellation"
        );
    }
}

/// A stall during the rejoin handshake defers admission (the reactor
/// drops the pending frame at its deadline; the worker retries) but
/// never wedges the round: with every handshake stalling, dropped
/// clients simply stay gone — all rounds still commit — while the same
/// chaos with clean handshakes re-admits them.
#[test]
fn stalled_rejoin_handshake_defers_admission_without_wedging() {
    let cfg = chaos_cfg(4, 10);
    let clean = run_chaos_tuned(&cfg, 0.25, 2, 7, |_| {});
    assert!(
        clean.generations.iter().any(|&g| g >= 1),
        "baseline chaos must re-admit someone: {:?}",
        clean.generations
    );
    let stalled =
        run_chaos_tuned(&cfg, 0.25, 2, 7, |pool| pool.set_handshake_stall_rate(1.0));
    assert_eq!(stalled.log.len(), cfg.rounds, "every round must still commit");
    assert!(stalled.handshake_stalls > 0, "the handshake chaos must actually fire");
    assert!(
        stalled.generations.iter().all(|&g| g == 0),
        "a permanently stalled handshake is never admitted: {:?}",
        stalled.generations
    );
    assert!(
        stalled.casualties >= clean.casualties.min(1),
        "drop chaos is untouched by handshake chaos"
    );
    assert!(stalled.params.iter().all(|p| p.is_finite()));
}

// ====================================================== TCP kill/rejoin

/// A scripted protocol round: answer a `Model` broadcast with a fixed
/// report and the echoed request — no real training, so the thread is
/// fast and fully deterministic.
fn scripted_round(stream: &mut TcpStream, id: u32, round: u32, base: u32) -> anyhow::Result<()> {
    scripted_round_r(stream, id, round, base, 12)
}

/// [`scripted_round`] with a configurable report width (the fixed index
/// window `base..base+r`, descending values).
fn scripted_round_r(
    stream: &mut TcpStream,
    id: u32,
    round: u32,
    base: u32,
    r: usize,
) -> anyhow::Result<()> {
    let idx: Vec<u32> = (0..r as u32).map(|j| base + j).collect();
    let val: Vec<f32> = (0..r).map(|j| (r - j) as f32).collect();
    let report = SparseVec::new(idx, val);
    send(
        stream,
        &Msg::Report { client_id: id, round, report: report.clone(), mean_loss: 1.0 },
        Codec::Raw,
    )?;
    let requested = match recv(stream, Codec::Raw)? {
        Msg::Request { indices, round: rr } if rr == round => indices,
        other => anyhow::bail!("expected Request, got {other:?}"),
    };
    let update = ragek::fl::client::Client::answer_request(&report, &requested);
    send(stream, &Msg::Update { client_id: id, round, update }, Codec::Raw)?;
    Ok(())
}

/// Acceptance pin: a worker killed mid-round no longer aborts training —
/// the round completes with the survivors, the dead client's ages keep
/// growing, and the reconnecting worker rejoins via the `Rejoin` frame
/// (model resync included) and contributes to later rounds.
#[test]
fn tcp_worker_killed_mid_round_rejoins_and_contributes() {
    let mut cfg = ExperimentConfig::mnist_smoke();
    cfg.n_clients = 2;
    cfg.payload = Payload::Delta;
    cfg.rounds = 6;
    cfg.train_n = 200;
    cfg.test_n = 64;
    cfg.eval_every = 0;
    cfg.io_timeout_ms = 2000;

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server_cfg = cfg.clone();
    let server = thread::spawn(move || {
        ragek::fl::distributed::run_server_on(&server_cfg, listener)
    });

    // worker 0: a real, healthy worker for the whole run
    let wcfg = cfg.clone();
    let worker = thread::spawn(move || {
        ragek::fl::distributed::run_worker(&wcfg, &format!("127.0.0.1:{}", addr.port()), 0)
    });

    // worker 1: scripted mortal — plays rounds 1-2, is killed mid-round 3
    // (right after receiving the broadcast), then reconnects with a
    // Rejoin frame and plays every remaining round
    let mortal = thread::spawn(move || -> anyhow::Result<()> {
        let mut s = TcpStream::connect(addr)?;
        send(&mut s, &Msg::Join { client_id: 1, codec: Codec::Raw }, Codec::Raw)?;
        loop {
            match recv(&mut s, Codec::Raw)? {
                Msg::Model { round, .. } => {
                    if round >= 3 {
                        drop(s); // killed mid-round: model received, no report
                        break;
                    }
                    scripted_round(&mut s, 1, round, 100)?;
                }
                Msg::Sit { .. } => continue,
                Msg::Shutdown => return Ok(()),
                other => anyhow::bail!("unexpected {other:?}"),
            }
        }
        // ---- the comeback: re-admission via the Rejoin handshake
        let mut s = TcpStream::connect(addr)?;
        send(
            &mut s,
            &Msg::Rejoin { client_id: 1, generation: 1, held_digest: 0, codec: Codec::Raw },
            Codec::Raw,
        )?;
        // the PS answers with the current global model (the resync)
        match recv(&mut s, Codec::Raw)? {
            Msg::Model { .. } => {}
            Msg::Shutdown => return Ok(()), // refused / run over: nothing to do
            other => anyhow::bail!("rejoin: expected Model resync, got {other:?}"),
        }
        loop {
            match recv(&mut s, Codec::Raw)? {
                Msg::Model { round, .. } => scripted_round(&mut s, 1, round, 100)?,
                Msg::Sit { .. } => continue,
                Msg::Shutdown => return Ok(()),
                other => anyhow::bail!("unexpected {other:?}"),
            }
        }
    });

    let report = server.join().unwrap().expect("the kill must not abort the run");
    let _ = worker.join().unwrap();
    mortal.join().unwrap().expect("the mortal's script must complete");

    assert_eq!(report.rounds, 6);
    assert!(report.casualties >= 1, "the kill must be observed as a casualty");
    assert_eq!(report.rejoins, 1, "exactly one Rejoin must have been admitted");
    // round 3 (index 2): the kill round — client 1 contributed nothing
    assert!(report.uploaded_log[2][1].is_empty(), "killed client uploads nothing");
    assert!(!report.uploaded_log[2][0].is_empty(), "the survivor finished round 3");
    // after the rejoin, client 1 contributes again
    let contributed_after = report.uploaded_log[3..]
        .iter()
        .any(|round| !round[1].is_empty());
    assert!(contributed_after, "the rejoined worker must contribute to later rounds");
    // while client 1 was gone, its (singleton-cluster) ages only grew:
    // replay the dense oracle over the full log
    let d = cfg.d();
    let mut dense = DenseAgeVector::new(d);
    for round in &report.uploaded_log {
        let before = dense.max_age();
        dense.update(&round[1]);
        if round[1].is_empty() {
            assert_eq!(dense.max_age(), before + 1, "absence must age uniformly");
        }
    }
}

// ==================================================== dynamic re-shard

/// A scripted, deterministic shard pool: every client reports a fixed
/// index window keyed by its **global** id — clients 2 and 3 share one
/// window, so the root's fleet-wide DBSCAN must pair them even though
/// they start on different shards. Implements [`Reshard`] by moving the
/// global ids themselves.
struct ScriptedPool {
    ids: Vec<usize>,
    backend: RustBackend,
    r: usize,
}

impl ScriptedPool {
    fn base(g: usize) -> u32 {
        if g == 2 || g == 3 {
            500 // the twins: identical request histories
        } else {
            100 * g as u32
        }
    }
}

impl ClientPool for ScriptedPool {
    fn n_clients(&self) -> usize {
        self.ids.len()
    }

    fn train_and_report(
        &mut self,
        _global: &[f32],
        cohort: &[usize],
    ) -> anyhow::Result<Vec<Option<ClientReport>>> {
        Ok(cohort
            .iter()
            .map(|&c| {
                let base = Self::base(self.ids[c]);
                let idx: Vec<u32> = (0..self.r as u32).map(|j| base + j).collect();
                let val: Vec<f32> = (0..self.r).map(|j| (self.r - j) as f32).collect();
                Some(ClientReport { report: SparseVec::new(idx, val), mean_loss: 1.0 })
            })
            .collect())
    }

    fn exchange(
        &mut self,
        requests: Option<&[Vec<u32>]>,
        cohort: &[usize],
    ) -> anyhow::Result<Vec<Option<SparseVec>>> {
        let reqs = requests.expect("rAge-k is PS-side");
        assert_eq!(reqs.len(), cohort.len());
        Ok(reqs
            .iter()
            .map(|req| Some(SparseVec::new(req.clone(), vec![1.0; req.len()])))
            .collect())
    }

    fn backend(&mut self) -> &mut dyn Backend {
        &mut self.backend
    }
}

impl Reshard for ScriptedPool {
    type Carry = usize;

    fn take_parts(&mut self) -> Vec<usize> {
        std::mem::take(&mut self.ids)
    }

    fn install_parts(&mut self, parts: Vec<usize>) {
        self.ids = parts;
    }
}

/// Acceptance pin: a recluster event with `shards >= 2` re-partitions
/// the clients across shard pools via `ClusterManager::shard_slices` —
/// here the twins (2, 3) start on *different* shards, the fleet-wide
/// DBSCAN pairs them at the round-2 boundary, and client 3's state is
/// handed to shard 0 — with the merged age vectors still equal to the
/// dense oracle after the hand-off.
#[test]
fn recluster_reshards_across_pools_with_exact_ages() {
    let mut cfg = ExperimentConfig::mnist_smoke();
    cfg.n_clients = 6;
    cfg.payload = Payload::Delta;
    cfg.participation = 1.0;
    cfg.recluster_every = 2;
    cfg.k = 2;
    cfg.r = 6;
    cfg.topology = Topology::Sharded { shards: 2, root_merge: MergeRule::Min };
    let d = cfg.d();

    let mut engine = ShardedEngine::new(&cfg, vec![0.0; d]).unwrap();
    assert_eq!(engine.slices(), &[vec![0, 1, 2], vec![3, 4, 5]], "static initial split");
    let mut pools: Vec<ScriptedPool> = engine
        .slices()
        .iter()
        .map(|slice| ScriptedPool {
            ids: slice.clone(),
            backend: RustBackend::new(cfg.r, cfg.lr_client, cfg.seed),
            r: cfg.r,
        })
        .collect();

    // rounds 1-2: static assignment; the round-2 boundary reclusters
    // fleet-wide and moves client 3 into shard 0 (twins 2+3 cluster,
    // shard_slices targets 3+3 -> [0,1]+[2,3] overfills shard 0)
    engine.run_round_serial(&mut pools).unwrap();
    assert!(engine.reshard_log.is_empty());
    let out2 = engine.run_round_serial(&mut pools).unwrap();
    assert_eq!(out2.reclustered, Some(5), "twins merge: 5 fleet-wide clusters");
    assert_eq!(
        engine.slices(),
        &[vec![0, 1, 2, 3], vec![4, 5]],
        "the recluster boundary must re-partition via shard_slices"
    );
    assert_eq!(engine.reshard_log, vec![(2, 1)], "exactly client 3 moved");
    assert_eq!(pools[0].ids, vec![0, 1, 2, 3], "shard 0 now drives the moved client");
    assert_eq!(pools[1].ids, vec![4, 5]);

    // rounds 3-4 run over the new assignment (round 4 reclusters again:
    // same groups, no further movement)
    engine.run_round_serial(&mut pools).unwrap();
    let out4 = engine.run_round_serial(&mut pools).unwrap();
    assert_eq!(out4.reclustered, Some(5));
    assert_eq!(engine.reshard_log.len(), 1, "a stable clustering must not re-move");

    // ---- dense eq.-(2) oracle across the merge + hand-off:
    // rounds 1-2 evolve per-client singletons; the boundary merges the
    // twins (elementwise min); rounds 3-4 update the twin cluster with
    // the union of their uploads and everyone else per-client.
    let log: Vec<Vec<Vec<u32>>> = engine.uploaded_log().iter().cloned().collect();
    assert_eq!(log.len(), 4);
    let mut dense: Vec<DenseAgeVector> = (0..6).map(|_| DenseAgeVector::new(d)).collect();
    for round in &log[..2] {
        for (g, uploaded) in round.iter().enumerate() {
            dense[g].update(uploaded);
        }
    }
    let mut twins = dense[2].clone();
    twins.merge_min(&dense[3]);
    for round in &log[2..] {
        for g in [0usize, 1, 4, 5] {
            dense[g].update(&round[g]);
        }
        let mut union: Vec<u32> = round[2].clone();
        union.extend_from_slice(&round[3]);
        union.sort_unstable();
        union.dedup();
        twins.update(&union);
    }
    let mut oracle = dense[0].clone();
    for v in [&dense[1], &twins, &dense[4], &dense[5]] {
        oracle.merge_min(v);
    }
    assert_eq!(
        engine.merged_ages().to_vec(),
        oracle.as_slice(),
        "merged ages must equal the dense oracle after the hand-off"
    );

    // the twins coordinate disjointly inside their (post-move) cluster
    let r3 = &log[2];
    assert!(
        r3[2].iter().all(|j| !r3[3].contains(j)),
        "clustered twins must receive disjoint requests: {:?} vs {:?}",
        r3[2],
        r3[3]
    );

    // fleet records rode along with the hand-off: everyone still Active
    for shard in engine.shards() {
        for i in 0..shard.fleet().n() {
            assert_eq!(shard.fleet().state(i), Membership::Active);
        }
    }
}

/// Satellite pin (DESIGN.md §8/§10): the sharded-TCP rejoin addressing
/// gap. Client 3 starts on shard 1, the round-2 recluster boundary
/// re-shards it onto shard 0 (twins 2+3 pair, exactly as in
/// [`recluster_reshards_across_pools_with_exact_ages`]), it is killed on
/// round 4's broadcast, and its comeback knocks on the *original*
/// shard-1 port with a **global**-id `Rejoin` frame — the PS must route
/// the handshake to whichever pool currently owns the id, admit it
/// there, and put the client back to work.
#[test]
fn tcp_rejoin_after_reshard_lands_on_new_shard() {
    let mut cfg = ExperimentConfig::mnist_smoke();
    cfg.n_clients = 6;
    cfg.payload = Payload::Delta;
    cfg.participation = 1.0;
    cfg.recluster_every = 2;
    cfg.k = 2;
    cfg.r = 6;
    cfg.rounds = 6;
    cfg.train_n = 200;
    cfg.test_n = 64;
    cfg.eval_every = 0;
    cfg.io_timeout_ms = 2000;
    cfg.topology = Topology::Sharded { shards: 2, root_merge: MergeRule::Min };

    let listeners: Vec<TcpListener> =
        (0..2).map(|_| TcpListener::bind("127.0.0.1:0").unwrap()).collect();
    let ports: Vec<u16> =
        listeners.iter().map(|l| l.local_addr().unwrap().port()).collect();
    let server_cfg = cfg.clone();
    let server = thread::spawn(move || {
        ragek::fl::distributed::run_sharded_server_on(&server_cfg, listeners)
    });

    // five healthy scripted workers on their static shards (global ids
    // 0,1,2 -> shard 0 locals 0,1,2; ids 4,5 -> shard 1 locals 1,2),
    // reporting the fixed per-id windows that pair ids 2 and 3 as twins
    let r = cfg.r;
    let mut healthy = Vec::new();
    for g in [0usize, 1, 2, 4, 5] {
        let (shard, local) = ragek::coordinator::topology::locate(6, 2, g);
        let port = ports[shard];
        healthy.push(thread::spawn(move || -> anyhow::Result<()> {
            let mut s = TcpStream::connect(("127.0.0.1", port))?;
            send(&mut s, &Msg::Join { client_id: local as u32, codec: Codec::Raw }, Codec::Raw)?;
            let base = if g == 2 { 500 } else { 100 * g as u32 };
            loop {
                match recv(&mut s, Codec::Raw)? {
                    Msg::Model { round, .. } => {
                        scripted_round_r(&mut s, g as u32, round, base, r)?
                    }
                    Msg::Sit { .. } => continue,
                    Msg::Shutdown => return Ok(()),
                    other => anyhow::bail!("worker {g}: unexpected {other:?}"),
                }
            }
        }));
    }

    // the mortal: global id 3, static shard 1 slot 0; shares the base-500
    // window with id 2, so the round-2 boundary moves it to shard 0
    let shard1_port = ports[1];
    let mortal = thread::spawn(move || -> anyhow::Result<()> {
        let mut s = TcpStream::connect(("127.0.0.1", shard1_port))?;
        send(&mut s, &Msg::Join { client_id: 0, codec: Codec::Raw }, Codec::Raw)?;
        loop {
            match recv(&mut s, Codec::Raw)? {
                Msg::Model { round, .. } => {
                    if round >= 4 {
                        drop(s); // killed mid-round, *after* the re-shard
                        break;
                    }
                    scripted_round_r(&mut s, 3, round, 500, 6)?;
                }
                Msg::Sit { .. } => continue,
                Msg::Shutdown => return Ok(()),
                other => anyhow::bail!("mortal: unexpected {other:?}"),
            }
        }
        // ---- the comeback: same port it always knew (shard 1), but a
        // global client id — the router must land it on shard 0
        let mut s = TcpStream::connect(("127.0.0.1", shard1_port))?;
        send(
            &mut s,
            &Msg::Rejoin { client_id: 3, generation: 1, held_digest: 0, codec: Codec::Raw },
            Codec::Raw,
        )?;
        match recv(&mut s, Codec::Raw)? {
            Msg::Model { .. } => {} // the resync from the owning shard
            Msg::Shutdown => return Ok(()), // refused / run over
            other => anyhow::bail!("rejoin: expected Model resync, got {other:?}"),
        }
        loop {
            match recv(&mut s, Codec::Raw)? {
                Msg::Model { round, .. } => scripted_round_r(&mut s, 3, round, 500, 6)?,
                Msg::Sit { .. } => continue,
                Msg::Shutdown => return Ok(()),
                other => anyhow::bail!("mortal (rejoined): unexpected {other:?}"),
            }
        }
    });

    let report = server.join().unwrap().expect("the kill must not abort the sharded run");
    for h in healthy {
        h.join().unwrap().expect("healthy workers must run to Shutdown");
    }
    mortal.join().unwrap().expect("the mortal's script must complete");

    assert_eq!(report.rounds, 6);
    assert!(report.casualties >= 1, "the kill must be observed as a casualty");
    assert_eq!(report.rejoins, 1, "the routed Rejoin must be admitted exactly once");
    // round 4 (index 3): the kill round — client 3 contributed nothing
    assert!(report.uploaded_log[3][3].is_empty(), "killed client uploads nothing");
    assert!(!report.uploaded_log[3][2].is_empty(), "its twin finished round 4");
    // after the routed rejoin, client 3 contributes again — possible only
    // if the handshake landed on the shard that owns the id *now*
    let contributed_after =
        report.uploaded_log[4..].iter().any(|round| !round[3].is_empty());
    assert!(contributed_after, "the rejoined worker must contribute via its new shard");
}
