//! Decoder robustness: the exhaustive hostile-bytes sweep (DESIGN.md
//! §13). A remote peer controls every byte the PS and workers parse, so
//! the decode stack has exactly two legal outcomes on malformed input —
//! `Ok` (the corruption happened to produce another well-formed frame)
//! or `Err` — and one illegal one: a panic. This suite walks, for every
//! `Msg` variant in every codec:
//!
//! - **every payload truncation point** through the blocking
//!   [`Msg::decode`] path — each strict prefix must return `Err`
//!   (length prefixes and the trailing-bytes check make a cleanly
//!   decodable strict prefix impossible by construction);
//! - **every frame truncation point** through the resumable
//!   [`RecvCursor`] path, where the stream ends in EOF — must `Err`,
//!   never complete, never spin;
//! - **every single-byte corruption** (xor 0x01 / 0x80 / 0xff at every
//!   offset) through both paths — outcome unasserted, termination and
//!   panic-freedom are the property. Header corruptions additionally
//!   must never complete a frame *silently shorter* than the magic +
//!   length contract allows.
//!
//! The sweep is a few thousand decodes of sub-100-byte frames — cheap
//! natively; it is deliberately NOT in the Miri allowlist (Miri runs it
//! ~100x slower for no extra soundness signal beyond what
//! `miri_memory.rs` already covers on representative cuts).

use ragek::fl::codec::FrameBuf;
use ragek::fl::transport::{IoStep, Msg, RecvCursor};
use ragek::fl::Codec;
use ragek::sparse::SparseVec;

const ALL: [Codec; 3] = [Codec::Raw, Codec::Packed, Codec::PackedF16];
const MASKS: [u8; 3] = [0x01, 0x80, 0xff];

/// One frame of every wire variant (mirrors the `wire_bytes` pin
/// fixture; the analyze lint keeps the canonical one exhaustive).
fn every_variant() -> Vec<Msg> {
    vec![
        Msg::Join { client_id: 3, codec: Codec::Packed },
        Msg::Rejoin { client_id: 3, generation: 2, held_digest: 1, codec: Codec::Packed },
        Msg::Model { round: 7, params: vec![] },
        Msg::Model { round: 7, params: vec![1.0, -2.5, 3.25] },
        Msg::Delta {
            round: 6,
            base_round: 2,
            digest: 99,
            delta: SparseVec::new(vec![10, 11, 900], vec![0.5, -0.5, 2.0]),
        },
        Msg::Delta { round: 6, base_round: 5, digest: 0, delta: SparseVec::default() },
        Msg::Report {
            client_id: 1,
            round: 2,
            report: SparseVec::new(vec![900, 5], vec![0.5, -0.25]),
            mean_loss: 2.25,
        },
        Msg::Report { client_id: 1, round: 2, report: SparseVec::new(vec![], vec![]), mean_loss: 0.5 },
        Msg::Request { round: 9, indices: vec![1, 200_000, 3] },
        Msg::Request { round: 9, indices: vec![] },
        Msg::Update {
            client_id: 0,
            round: 1,
            update: SparseVec::new(vec![4, 8, 15], vec![0.1, 0.2, 0.3]),
        },
        Msg::Update { client_id: 0, round: 1, update: SparseVec::new(vec![], vec![]) },
        Msg::Shutdown,
        Msg::Sit { round: 4 },
    ]
}

/// Drive a whole byte slice through the resumable read path. `&[u8]`'s
/// `Read` impl never blocks and ends in `Ok(0)`, so this terminates with
/// either a completed frame or the cursor's error.
fn recv_all(bytes: &[u8]) -> Result<Vec<u8>, anyhow::Error> {
    let mut r: &[u8] = bytes;
    let mut cur = RecvCursor::new();
    let mut fb = FrameBuf::new();
    loop {
        match cur.advance(&mut r, &mut fb)? {
            IoStep::Done => return Ok(fb.recv_payload().to_vec()),
            IoStep::Pending => unreachable!("&[u8] never reports WouldBlock"),
        }
    }
}

#[test]
fn every_payload_truncation_point_errors() {
    for codec in ALL {
        for m in every_variant() {
            let frame = m.encode(codec);
            let payload = &frame[8..];
            for cut in 0..payload.len() {
                assert!(
                    Msg::decode(&payload[..cut], codec).is_err(),
                    "{codec:?} {m:?}: strict prefix of {cut}/{} bytes decoded cleanly",
                    payload.len()
                );
            }
        }
    }
}

#[test]
fn every_frame_truncation_point_errors_through_recv_cursor() {
    for codec in ALL {
        for m in every_variant() {
            let frame = m.encode(codec);
            for cut in 0..frame.len() {
                let res = recv_all(&frame[..cut]);
                assert!(
                    res.is_err(),
                    "{codec:?} {m:?}: frame cut at {cut}/{} completed through RecvCursor",
                    frame.len()
                );
            }
        }
    }
}

#[test]
fn every_single_byte_payload_corruption_is_panic_free() {
    for codec in ALL {
        for m in every_variant() {
            let frame = m.encode(codec);
            let payload = &frame[8..];
            for pos in 0..payload.len() {
                for mask in MASKS {
                    let mut p = payload.to_vec();
                    p[pos] ^= mask;
                    // outcome is free (a flipped bit can form another
                    // valid message); not panicking is the property —
                    // and a decoded Ok must re-encode without panicking
                    // either, since the PS logs/echoes what it accepts.
                    if let Ok(back) = Msg::decode(&p, codec) {
                        let _ = back.encode(codec);
                    }
                }
            }
        }
    }
}

#[test]
fn every_single_byte_frame_corruption_is_panic_free_through_recv_cursor() {
    for codec in ALL {
        for m in every_variant() {
            let frame = m.encode(codec);
            for pos in 0..frame.len() {
                for mask in MASKS {
                    let mut f = frame.clone();
                    f[pos] ^= mask;
                    match recv_all(&f) {
                        // corrupting the length downward can complete a
                        // short frame; its payload then faces decode,
                        // which must stay panic-free like everything else
                        Ok(payload) => {
                            let _ = Msg::decode(&payload, codec);
                        }
                        Err(_) => {}
                    }
                }
            }
        }
    }
}

/// The one corruption with a hard *semantic* requirement: flipping any
/// bit of the 4-byte magic must kill the frame at the header, before a
/// single payload byte is interpreted.
#[test]
fn magic_corruption_never_reaches_the_payload() {
    let frame = Msg::Sit { round: 4 }.encode(Codec::Raw);
    for pos in 0..4 {
        for mask in MASKS {
            let mut f = frame.clone();
            f[pos] ^= mask;
            let err = recv_all(&f).expect_err("corrupt magic must not complete");
            assert!(
                format!("{err:#}").contains("magic"),
                "pos {pos} mask {mask:#x}: expected a magic error, got: {err:#}"
            );
        }
    }
}
