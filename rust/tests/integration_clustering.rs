//! Clustering integration: the full eq. (3) + DBSCAN pipeline must
//! rediscover the planted client pairs from nothing but request
//! histories (the Fig. 2 claim).

use ragek::config::ExperimentConfig;
use ragek::coordinator::strategies::StrategyKind;
use ragek::data::partition::paper_pair_truth;
use ragek::fl::trainer::Trainer;

fn rand_index(a: &[usize], b: &[usize]) -> f64 {
    let n = a.len();
    let mut agree = 0usize;
    let mut total = 0usize;
    for i in 0..n {
        for j in (i + 1)..n {
            if (a[i] == a[j]) == (b[i] == b[j]) {
                agree += 1;
            }
            total += 1;
        }
    }
    agree as f64 / total as f64
}

#[test]
fn recovers_planted_pairs_on_mnist() {
    let mut cfg = ExperimentConfig::mnist_scaled();
    cfg.rounds = 44; // two reclustering windows (M = 20)
    cfg.train_n = 2000;
    cfg.test_n = 256;
    cfg.eval_every = 0;
    let mut t = Trainer::from_config(&cfg).unwrap();
    let report = t.run().unwrap();
    let truth = paper_pair_truth(cfg.n_clients);
    let ri = rand_index(&report.cluster_labels, &truth);
    assert!(
        ri >= 0.9,
        "clustering must recover the pairs: labels {:?} truth {truth:?} (rand {ri:.3})",
        report.cluster_labels
    );
}

#[test]
fn connectivity_matrix_develops_pair_structure() {
    let mut cfg = ExperimentConfig::mnist_scaled();
    cfg.rounds = 30;
    cfg.train_n = 1500;
    cfg.test_n = 256;
    cfg.eval_every = 0;
    let mut t = Trainer::from_config(&cfg).unwrap();
    t.heatmap_rounds = vec![30];
    let report = t.run().unwrap();
    let (_, m) = &report.heatmaps[0];
    // mean within-pair similarity must dominate cross-pair similarity
    let mut within = Vec::new();
    let mut across = Vec::new();
    for i in 0..10 {
        for j in 0..10 {
            if i == j {
                continue;
            }
            if i / 2 == j / 2 {
                within.push(m[i][j]);
            } else {
                across.push(m[i][j]);
            }
        }
    }
    let mw = within.iter().sum::<f64>() / within.len() as f64;
    let ma = across.iter().sum::<f64>() / across.len() as f64;
    assert!(
        mw > ma * 1.5,
        "within-pair similarity {mw:.3} must dominate cross-pair {ma:.3}"
    );
}

#[test]
fn no_reclustering_without_age_strategy() {
    let mut cfg = ExperimentConfig::mnist_smoke();
    cfg.strategy = StrategyKind::RTopK;
    cfg.rounds = 8;
    let mut t = Trainer::from_config(&cfg).unwrap();
    let report = t.run().unwrap();
    // rTop-k has no PS-side age state: everyone stays a singleton
    assert_eq!(report.cluster_labels, (0..cfg.n_clients).collect::<Vec<_>>());
}

#[test]
fn iid_clients_may_all_cluster_together() {
    // with iid data all clients look alike: DBSCAN should put them in few
    // clusters (usually one) — and the run must stay healthy regardless
    let mut cfg = ExperimentConfig::mnist_smoke();
    cfg.partition = ragek::data::partition::Scheme::Iid;
    cfg.rounds = 12;
    cfg.recluster_every = 4;
    let mut t = Trainer::from_config(&cfg).unwrap();
    let report = t.run().unwrap();
    let distinct: std::collections::HashSet<_> = report.cluster_labels.iter().collect();
    assert!(
        distinct.len() <= cfg.n_clients,
        "cluster count in range: {:?}",
        report.cluster_labels
    );
}
