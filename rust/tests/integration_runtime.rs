//! Cross-layer numerics: the PJRT-executed HLO artifacts vs the pure-Rust
//! oracle, on identical inputs (the `mnist_init.bin` parameters dumped at
//! AOT time). Skips cleanly when `make artifacts` has not run, and is
//! compiled only under the `xla-runtime` feature (PJRT bindings).

#![cfg(feature = "xla-runtime")]

use ragek::backend::{Backend, ClientState, GlobalState, RustBackend, XlaBackend};
use ragek::coordinator::aggregator::Aggregate;
use ragek::nn::mlp;
use ragek::runtime::{lit_f32, lit_i32, to_f32, to_i32, Runtime};
use ragek::sparse::SparseVec;
use ragek::util::rng::Rng;

const ART: &str = "artifacts";

fn artifacts_available() -> bool {
    std::path::Path::new(ART).join("manifest.json").exists()
}

macro_rules! require_artifacts {
    () => {
        if !artifacts_available() {
            eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
            return;
        }
    };
}

fn batch(b: usize, seed: u64) -> (Vec<f32>, Vec<i32>) {
    let mut rng = Rng::new(seed);
    let mut x = vec![0.0f32; b * 784];
    for v in x.iter_mut() {
        *v = rng.uniform() as f32;
    }
    let y: Vec<i32> = (0..b).map(|i| (i % 10) as i32).collect();
    (x, y)
}

#[test]
fn manifest_matches_table1() {
    require_artifacts!();
    let rt = Runtime::load_one(ART, "mnist", "eval_batch").unwrap();
    assert_eq!(rt.model().d, 39760);
    assert_eq!(rt.model().r, 75);
    assert_eq!(rt.model().k, 10);
    let init = rt.init_params().unwrap();
    assert_eq!(init.len(), 39760);
}

#[test]
fn eval_matches_rust_oracle() {
    require_artifacts!();
    let mut xla = XlaBackend::new(ART, "mnist", 75).unwrap();
    let params = xla.init_params().unwrap();
    let b = xla.runtime().model().batch;
    let (x, y) = batch(b, 3);
    let (xl_loss, xl_correct) = xla.eval(&params, &x, &y).unwrap();
    let (rs_loss, rs_correct) = mlp::evaluate(&params, &x, &y);
    assert_eq!(xl_correct, rs_correct, "correct counts must agree exactly");
    let rel = (xl_loss - rs_loss).abs() / rs_loss.abs().max(1e-6);
    assert!(rel < 1e-3, "loss mismatch: xla {xl_loss} vs rust {rs_loss}");
}

#[test]
fn local_round_matches_rust_backend() {
    require_artifacts!();
    let mut xla = XlaBackend::new(ART, "mnist", 75).unwrap();
    let m = xla.runtime().model().clone();
    let (h, b) = (m.h_scan, m.batch);
    let params = xla.init_params().unwrap();

    let mut rng = Rng::new(11);
    let mut xs = vec![0.0f32; h * b * 784];
    for v in xs.iter_mut() {
        *v = rng.uniform() as f32;
    }
    let ys: Vec<i32> = (0..h * b).map(|i| (i % 10) as i32).collect();

    let mut st_x = ClientState::new(params.clone());
    let out_x = xla.local_round(&mut st_x, &xs, &ys, h, b).unwrap();

    let mut rust = RustBackend::new(75, m.lr as f32, 0);
    let mut st_r = ClientState::new(params);
    let out_r = rust.local_round(&mut st_r, &xs, &ys, h, b).unwrap();

    // parameters after H Adam steps agree to float tolerance
    let max_diff = st_x
        .params
        .iter()
        .zip(&st_r.params)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 5e-5, "param divergence {max_diff}");
    assert!((out_x.mean_loss - out_r.mean_loss).abs() < 1e-3);

    // top-r reports: indices are tie-break sensitive; require high overlap
    // and identical leading entries
    let set_x: std::collections::HashSet<_> = out_x.report.idx.iter().collect();
    let overlap = out_r.report.idx.iter().filter(|i| set_x.contains(i)).count();
    assert!(
        overlap >= 70,
        "top-75 reports overlap only {overlap}/75: gradients diverged"
    );
    assert_eq!(out_x.report.idx[..10], out_r.report.idx[..10]);
}

#[test]
fn ragek_select_artifact_matches_rust_selection() {
    require_artifacts!();
    let rt = Runtime::load_one(ART, "mnist", "ragek_select").unwrap();
    let m = rt.model().clone();
    let d = m.d;
    let mut rng = Rng::new(5);
    let mut grad = vec![0.0f32; d];
    rng.fill_gaussian(&mut grad, 1.0);
    // build an age vector with structure: old ages on a band of indices
    let mut age_rust = ragek::age::AgeVector::new(d);
    for round in 0..20 {
        let sel: Vec<u32> = (0..d as u32).filter(|j| j % 20 != round % 20).collect();
        age_rust.update(&sel);
    }
    let age_i32: Vec<i32> = age_rust.to_vec().into_iter().map(|a| a as i32).collect();

    let outs = rt
        .call(
            "ragek_select",
            &[
                lit_f32(&grad, &[d as i64]).unwrap(),
                lit_i32(&age_i32, &[d as i64]).unwrap(),
            ],
        )
        .unwrap();
    let sel_idx: Vec<u32> = to_i32(&outs[0]).unwrap().into_iter().map(|i| i as u32).collect();
    let sel_val = to_f32(&outs[1]).unwrap();
    let new_age = to_i32(&outs[2]).unwrap();

    // rust mirror: top-r by |g|, then k oldest
    let report = ragek::sparse::topk_abs_sparse(&grad, m.r);
    let rust_sel =
        ragek::coordinator::selection::select_oldest_k(&age_rust, &report.idx, m.k);
    let mut a = sel_idx.clone();
    let mut b = rust_sel.clone();
    a.sort_unstable();
    b.sort_unstable();
    assert_eq!(a, b, "selected index sets must agree");
    for (j, v) in sel_idx.iter().zip(&sel_val) {
        assert!((grad[*j as usize] - v).abs() < 1e-6);
    }
    // eq. (2) on the artifact side
    let sel_set: std::collections::HashSet<u32> = sel_idx.into_iter().collect();
    for j in (0..d).step_by(997) {
        let want = if sel_set.contains(&(j as u32)) {
            0
        } else {
            age_rust.get(j) as i32 + 1
        };
        assert_eq!(new_age[j], want, "age mismatch at {j}");
    }
}

#[test]
fn apply_sparse_matches_rust_adam() {
    require_artifacts!();
    let mut xla = XlaBackend::new(ART, "mnist", 75).unwrap();
    let params = xla.init_params().unwrap();
    let d = params.len();
    let mut rng = Rng::new(9);
    let idx: Vec<u32> = rng.choose_k(d, 40).into_iter().map(|x| x as u32).collect();
    let val: Vec<f32> = (0..40).map(|_| rng.gaussian() as f32).collect();
    let mut agg = Aggregate::new();
    agg.push(SparseVec::new(idx, val));

    let mut gx = GlobalState::new(params.clone());
    xla.server_apply(&mut gx, &agg, 1.0, 1e-4).unwrap();

    let mut rust = RustBackend::new(75, 1e-4, 0);
    let mut gr = GlobalState::new(params);
    rust.server_apply(&mut gr, &agg, 1.0, 1e-4).unwrap();

    let max_diff = gx
        .params
        .iter()
        .zip(&gr.params)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-6, "server apply divergence {max_diff}");
    assert_eq!(gx.adam.t, gr.adam.t);
}

#[test]
fn xla_end_to_end_smoke_training() {
    require_artifacts!();
    use ragek::config::{BackendKind, ExperimentConfig};
    let mut cfg = ExperimentConfig::mnist_scaled();
    cfg.backend = BackendKind::Xla;
    cfg.rounds = 3;
    cfg.train_n = 600;
    cfg.test_n = 256;
    cfg.eval_every = 3;
    let mut t = ragek::fl::trainer::Trainer::from_config(&cfg).unwrap();
    let report = t.run().unwrap();
    assert_eq!(report.history.rounds.len(), 3);
    assert!(report.history.rounds.iter().all(|r| r.train_loss.is_finite()));
}
