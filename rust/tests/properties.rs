//! Cross-module property tests (via the in-repo `testing::prop_check`
//! substrate — proptest is unavailable offline): coordinator invariants
//! the paper's protocol depends on.

use ragek::age::{AgeVector, DenseAgeVector};
use ragek::coordinator::aggregator::Aggregate;
use ragek::coordinator::selection::{select_disjoint, select_oldest_k};
use ragek::sparse::{topk_abs_sparse, SparseVec};
use ragek::testing::{prop_check, Gen};

fn random_age(g: &mut Gen, d: usize) -> AgeVector {
    let mut age = AgeVector::new(d);
    let rounds = g.usize_in(0, 25);
    for _ in 0..rounds {
        let k = g.usize_in(1, (d / 4).max(1));
        let sel = g.vec_u32_distinct(d, k);
        age.update(&sel);
    }
    age
}

/// The invariant hierarchical (multi-PS) aggregation relies on: lazy
/// age-vector merges are **commutative and associative** across operands
/// with arbitrarily divergent epochs, and always agree with the
/// [`DenseAgeVector`] oracle. The root aggregator may therefore combine
/// shard vectors in any order — `merge(merge(a, b), c)` from a shard that
/// ran 25 epochs and one that ran 2 is the same fleet-wide staleness
/// view as any other association.
#[test]
fn age_merge_is_commutative_and_associative_across_epochs() {
    for (rule, dense_rule) in [
        (
            AgeVector::merge_min as fn(&mut AgeVector, &AgeVector),
            DenseAgeVector::merge_min as fn(&mut DenseAgeVector, &DenseAgeVector),
        ),
        (AgeVector::merge_max, DenseAgeVector::merge_max),
    ] {
        prop_check("age-merge-comm-assoc", 150, |g| {
            let d = g.usize_in(5, 120);
            // independently evolved vectors with deliberately divergent
            // epochs (0..25 rounds each), mirrored into the dense oracle
            let mut lazies = Vec::new();
            let mut denses = Vec::new();
            for _ in 0..3 {
                let mut lazy = AgeVector::new(d);
                let mut dense = DenseAgeVector::new(d);
                for _ in 0..g.usize_in(0, 25) {
                    let k = g.usize_in(1, (d / 4).max(1));
                    let sel = g.vec_u32_distinct(d, k);
                    lazy.update(&sel);
                    dense.update(&sel);
                }
                lazies.push(lazy);
                denses.push(dense);
            }
            let [a, b, c] = &lazies[..] else { unreachable!() };

            // commutativity: a ∪ b == b ∪ a (equality is on ages)
            let mut ab = a.clone();
            rule(&mut ab, b);
            let mut ba = b.clone();
            rule(&mut ba, a);
            if ab != ba {
                return Err(format!(
                    "merge not commutative: {:?} vs {:?}",
                    ab.to_vec(),
                    ba.to_vec()
                ));
            }

            // associativity: (a ∪ b) ∪ c == a ∪ (b ∪ c)
            let mut ab_c = ab.clone();
            rule(&mut ab_c, c);
            let mut bc = b.clone();
            rule(&mut bc, c);
            let mut a_bc = a.clone();
            rule(&mut a_bc, &bc);
            if ab_c != a_bc {
                return Err(format!(
                    "merge not associative: {:?} vs {:?}",
                    ab_c.to_vec(),
                    a_bc.to_vec()
                ));
            }

            // and the whole algebra agrees with the dense oracle
            let mut oracle = denses[0].clone();
            dense_rule(&mut oracle, &denses[1]);
            dense_rule(&mut oracle, &denses[2]);
            if ab_c.to_vec() != oracle.as_slice() {
                return Err(format!(
                    "lazy merge diverged from dense oracle: {:?} vs {:?}",
                    ab_c.to_vec(),
                    oracle.as_slice()
                ));
            }

            // merged vectors keep obeying eq. (2): one more update shifts
            // every unselected age by +1 on both representations
            let k = g.usize_in(1, (d / 4).max(1));
            let sel = g.vec_u32_distinct(d, k);
            let mut lazy_next = ab_c.clone();
            lazy_next.update(&sel);
            let mut dense_next = oracle.clone();
            dense_next.update(&sel);
            if lazy_next.to_vec() != dense_next.as_slice() {
                return Err("post-merge eq. (2) update diverged from the oracle".into());
            }
            Ok(())
        });
    }
}

#[test]
fn selection_returns_k_distinct_report_members_maximizing_age() {
    prop_check("selection-invariants", 200, |g| {
        let d = g.usize_in(20, 500);
        let r = g.usize_in(2, d.min(40));
        let k = g.usize_in(1, r);
        let age = random_age(g, d);
        let report = g.vec_u32_distinct(d, r);
        let sel = select_oldest_k(&age, &report, k);
        if sel.len() != k {
            return Err(format!("len {} != k {k}", sel.len()));
        }
        let set: std::collections::HashSet<_> = sel.iter().collect();
        if set.len() != k {
            return Err("duplicates in selection".into());
        }
        if !sel.iter().all(|j| report.contains(j)) {
            return Err("selected index outside report".into());
        }
        let min_sel = sel.iter().map(|&j| age.get(j as usize)).min().unwrap();
        for &j in &report {
            if !set.contains(&j) && age.get(j as usize) > min_sel {
                return Err(format!("unselected {j} older than selected minimum"));
            }
        }
        Ok(())
    });
}

#[test]
fn disjoint_selection_never_overlaps_until_exhaustion() {
    prop_check("disjoint-selection", 200, |g| {
        let d = g.usize_in(50, 400);
        let r = g.usize_in(4, 30.min(d));
        let k = g.usize_in(1, r / 2);
        let n_members = g.usize_in(2, 4);
        let age = random_age(g, d);
        let reports: Vec<Vec<u32>> =
            (0..n_members).map(|_| g.vec_u32_distinct(d, r)).collect();
        let refs: Vec<&[u32]> = reports.iter().map(|r| r.as_slice()).collect();
        let sels = select_disjoint(&age, &refs, k);

        // every member uploads exactly k distinct in-report indices
        for (sel, report) in sels.iter().zip(&reports) {
            if sel.len() != k {
                return Err("wrong k".into());
            }
            if !sel.iter().all(|j| report.contains(j)) {
                return Err("outside report".into());
            }
        }
        // union covers min(sum k, union of reports) — i.e. overlap only
        // when a report is exhausted
        let union_reports: std::collections::HashSet<u32> =
            reports.iter().flatten().cloned().collect();
        let union_sel: std::collections::HashSet<u32> =
            sels.iter().flatten().cloned().collect();
        let expected = (n_members * k).min(union_reports.len());
        // the greedy can fall short only when a *specific* report ran dry;
        // verify no overlap happened while the report still had unassigned
        // indices available
        let mut taken: std::collections::HashSet<u32> = Default::default();
        for (sel, report) in sels.iter().zip(&reports) {
            for &j in sel {
                if taken.contains(&j) {
                    // overlap is only legal if every report index was taken
                    let free = report.iter().any(|x| !taken.contains(x));
                    if free {
                        return Err(format!("overlapped on {j} while report had free indices"));
                    }
                }
            }
            for &j in sel {
                taken.insert(j);
            }
        }
        let _ = (union_sel, expected);
        Ok(())
    });
}

#[test]
fn eq2_age_update_is_a_partition() {
    prop_check("eq2-partition", 200, |g| {
        let d = g.usize_in(1, 2000);
        let mut age = random_age(g, d);
        let before: Vec<u32> = age.to_vec();
        let k = g.usize_in(1, d);
        let sel = g.vec_u32_distinct(d, k);
        age.update(&sel);
        let sel_set: std::collections::HashSet<u32> = sel.into_iter().collect();
        for j in 0..d {
            let want = if sel_set.contains(&(j as u32)) { 0 } else { before[j] + 1 };
            if age.get(j) != want {
                return Err(format!("age[{j}] = {} want {want}", age.get(j)));
            }
        }
        Ok(())
    });
}

/// The lazy epoch-offset [`AgeVector`] must agree with the dense eq. (2)
/// sweep ([`DenseAgeVector`]) under arbitrary interleavings of the
/// operations the PS performs over a vector's lifetime: per-round
/// updates, min/max merges on cluster formation (operands at *different*
/// epochs, exactly what reclustering produces), and resets on splits.
#[test]
fn lazy_age_matches_dense_oracle() {
    prop_check("lazy-age-oracle", 150, |g| {
        let d = g.usize_in(1, 400);
        let mut lazy = AgeVector::new(d);
        let mut dense = DenseAgeVector::new(d);
        let ops = g.usize_in(1, 30);
        for _ in 0..ops {
            match g.usize_in(0, 4) {
                0 | 1 => {
                    // eq. (2) round update (the common case)
                    let k = g.usize_in(1, d);
                    let sel = g.vec_u32_distinct(d, k);
                    lazy.update(&sel);
                    dense.update(&sel);
                }
                2 | 3 => {
                    // merge with a sibling that lived through its own
                    // (different-length) history
                    let mut other_lazy = AgeVector::new(d);
                    let mut other_dense = DenseAgeVector::new(d);
                    for _ in 0..g.usize_in(0, 8) {
                        let k = g.usize_in(1, d);
                        let sel = g.vec_u32_distinct(d, k);
                        other_lazy.update(&sel);
                        other_dense.update(&sel);
                    }
                    if g.bool() {
                        lazy.merge_min(&other_lazy);
                        dense.merge_min(&other_dense);
                    } else {
                        lazy.merge_max(&other_lazy);
                        dense.merge_max(&other_dense);
                    }
                }
                _ => {
                    // cluster-split reset
                    lazy.reset();
                    dense.reset();
                }
            }
            if lazy.to_vec() != dense.as_slice() {
                return Err(format!(
                    "lazy {:?} != dense {:?}",
                    lazy.to_vec(),
                    dense.as_slice()
                ));
            }
            if lazy.max_age() != dense.max_age() {
                return Err("max_age mismatch".into());
            }
        }
        // gather (the selection input) agrees on a random index subset
        let k = g.usize_in(1, d);
        let idx = g.vec_u32_distinct(d, k);
        let want: Vec<f32> = idx.iter().map(|&j| dense.get(j as usize) as f32).collect();
        if lazy.gather(&idx) != want {
            return Err("gather mismatch".into());
        }
        Ok(())
    });
}

/// Partial participation and eq. (2): a cluster whose clients all sat a
/// round out must age **uniformly by exactly +1** — absence is pure
/// staleness, never a reset — while participating clients' requested
/// indices reset to 0 and their other indices age by +1.
#[test]
fn off_cohort_cluster_ages_grow_monotonically() {
    use ragek::clustering::{DbscanParams, MergeRule};
    use ragek::coordinator::server::{ParameterServer, PsConfig};
    use ragek::coordinator::strategies::StrategyKind;
    prop_check("off-cohort-age-growth", 60, |g| {
        let n = g.usize_in(2, 6);
        let d = g.usize_in(20, 120);
        let k = g.usize_in(1, 4);
        // recluster_every = 0: clusters stay singletons, so per-client
        // and per-cluster age vectors coincide
        let mut ps = ParameterServer::new(PsConfig {
            d,
            n_clients: n,
            k,
            strategy: StrategyKind::RageK,
            recluster_every: 0,
            dbscan: DbscanParams::default(),
            merge_rule: MergeRule::Min,
        });
        let rounds = g.usize_in(1, 12);
        for _ in 0..rounds {
            let m = g.usize_in(1, n);
            let mut cohort = g.rng.choose_k(n, m);
            cohort.sort_unstable();
            let r = k + g.usize_in(0, 6);
            let reports: Vec<Vec<u32>> =
                cohort.iter().map(|_| g.vec_u32_distinct(d, r)).collect();
            let before: Vec<Vec<u32>> =
                (0..n).map(|i| ps.clusters().age_of_client(i).to_vec()).collect();

            let requests = ps.select_requests_cohort(&cohort, &reports);
            let mut uploaded: Vec<Vec<u32>> = vec![Vec::new(); n];
            for (p, &c) in cohort.iter().enumerate() {
                uploaded[c] = requests[p].clone();
            }
            ps.record_round(&uploaded);

            for i in 0..n {
                let after = ps.clusters().age_of_client(i).to_vec();
                let sel: std::collections::HashSet<u32> =
                    uploaded[i].iter().copied().collect();
                for j in 0..d {
                    let want = if sel.contains(&(j as u32)) {
                        0 // requested this round: reset per eq. (2)
                    } else {
                        before[i][j] + 1 // everything else ages, absent or not
                    };
                    if after[j] != want {
                        return Err(format!(
                            "client {i} (cohort {cohort:?}): age[{j}] = {} want {want}",
                            after[j]
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

/// The stamp-versioned [`CohortMap`] must be observationally identical
/// to the naive rebuild-a-`Vec` inverse map it replaced (the old
/// `cohort_positions`), across arbitrary re-keyings — including shrinking
/// and growing `n` mid-stream, which a dynamic re-shard does.
///
/// [`CohortMap`]: ragek::coordinator::engine::CohortMap
#[test]
fn cohort_map_matches_naive_position_vector() {
    use ragek::coordinator::engine::CohortMap;
    // the replaced implementation, verbatim
    fn naive(n: usize, cohort: &[usize]) -> Vec<usize> {
        let mut pos = vec![usize::MAX; n];
        for (p, &c) in cohort.iter().enumerate() {
            pos[c] = p;
        }
        pos
    }
    prop_check("cohort-map-oracle", 150, |g| {
        let mut map = CohortMap::new();
        let rekeys = g.usize_in(1, 12);
        for _ in 0..rekeys {
            let n = g.usize_in(1, 64);
            let m = g.usize_in(1, n);
            let mut cohort: Vec<usize> = g.rng.choose_k(n, m);
            cohort.sort_unstable();
            map.set(n, &cohort);
            let want = naive(n, &cohort);
            for (i, &w) in want.iter().enumerate() {
                if map.slot(i) != w {
                    return Err(format!(
                        "n={n} cohort={cohort:?}: slot({i}) = {} want {w}",
                        map.slot(i)
                    ));
                }
                let as_opt = if w == usize::MAX { None } else { Some(w) };
                if map.get(i) != as_opt {
                    return Err(format!("get({i}) disagrees with slot({i})"));
                }
            }
        }
        Ok(())
    });
}

/// The dynamic re-shard hand-off must not lose age information: carving
/// a fleet-wide [`ClusterManager`] into arbitrary (sorted, disjoint)
/// slices with `split_cluster_manager` — straddling clusters get cloned
/// vectors — and merging every per-shard cluster vector back together
/// yields exactly the merge of the original cluster vectors, checked
/// against the [`DenseAgeVector`] oracle for both merge rules.
///
/// [`ClusterManager`]: ragek::clustering::ClusterManager
#[test]
fn reshard_handoff_preserves_merged_ages() {
    use ragek::clustering::{ClusterManager, MergeRule};
    use ragek::coordinator::topology::split_cluster_manager;
    prop_check("reshard-age-handoff", 100, |g| {
        let n = g.usize_in(2, 12);
        let d = g.usize_in(4, 60);
        // random clustering of 0..n: assign each client a group id, then
        // evolve one (lazy + dense) age vector per group
        let n_groups = g.usize_in(1, n);
        let mut assign: Vec<usize> = (0..n).map(|_| g.usize_in(0, n_groups - 1)).collect();
        for (gid, a) in assign.iter_mut().enumerate().take(n_groups) {
            *a = gid; // every group non-empty
        }
        let mut groups: Vec<Vec<usize>> = vec![Vec::new(); n_groups];
        for (c, &gid) in assign.iter().enumerate() {
            groups[gid].push(c);
        }
        groups.retain(|grp| !grp.is_empty());
        groups.sort();
        let mut ages = Vec::new();
        let mut dense = Vec::new();
        for _ in 0..groups.len() {
            let mut lazy = AgeVector::new(d);
            let mut dns = DenseAgeVector::new(d);
            for _ in 0..g.usize_in(0, 10) {
                let k = g.usize_in(1, d);
                let sel = g.vec_u32_distinct(d, k);
                lazy.update(&sel);
                dns.update(&sel);
            }
            ages.push(lazy);
            dense.push(dns);
        }
        let fleet =
            ClusterManager::from_parts(n, d, MergeRule::Min, groups.clone(), ages.clone());

        // random disjoint sorted slices (NOT cluster-aligned on purpose:
        // the straddle path must preserve ages too)
        let n_slices = g.usize_in(1, n);
        let order = g.rng.choose_k(n, n);
        let mut slices: Vec<Vec<usize>> = vec![Vec::new(); n_slices];
        for (i, &c) in order.iter().enumerate() {
            slices[i % n_slices].push(c);
        }
        slices.retain(|s| !s.is_empty());
        for s in slices.iter_mut() {
            s.sort_unstable();
        }

        for rule in [MergeRule::Min, MergeRule::Max] {
            // merge of every per-shard cluster vector after the hand-off
            let mut merged: Option<AgeVector> = None;
            for slice in &slices {
                let part = split_cluster_manager(&fleet, slice, d, rule);
                for c in 0..part.n_clusters() {
                    let v = part.age_of_cluster(c);
                    match &mut merged {
                        None => merged = Some(v.clone()),
                        Some(a) => match rule {
                            MergeRule::Min => a.merge_min(v),
                            MergeRule::Max => a.merge_max(v),
                        },
                    }
                }
            }
            // dense oracle over the ORIGINAL cluster vectors
            let mut oracle = dense[0].clone();
            for v in &dense[1..] {
                match rule {
                    MergeRule::Min => oracle.merge_min(v),
                    MergeRule::Max => oracle.merge_max(v),
                }
            }
            let merged = merged.expect("at least one cluster");
            if merged.to_vec() != oracle.as_slice() {
                return Err(format!(
                    "{rule:?}: hand-off changed the merged ages: {:?} vs {:?}",
                    merged.to_vec(),
                    oracle.as_slice()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn aggregation_is_linear_and_order_invariant() {
    prop_check("aggregation-linearity", 100, |g| {
        let d = g.usize_in(10, 300);
        let n = g.usize_in(1, 6);
        let parts: Vec<SparseVec> = (0..n)
            .map(|_| {
                let k = g.usize_in(1, d.min(20));
                let idx = g.vec_u32_distinct(d, k);
                let val = g.vec_f32(k, 2.0);
                SparseVec::new(idx, val)
            })
            .collect();

        let mut agg = Aggregate::new();
        for p in &parts {
            agg.push(p.clone());
        }
        let dense = agg.to_dense(d, 1.0);

        // order-invariance
        let mut agg_rev = Aggregate::new();
        for p in parts.iter().rev() {
            agg_rev.push(p.clone());
        }
        let dense_rev = agg_rev.to_dense(d, 1.0);
        for (a, b) in dense.iter().zip(&dense_rev) {
            if (a - b).abs() > 1e-4 {
                return Err("order dependence".into());
            }
        }

        // linearity: agg == sum of individual denses
        let mut manual = vec![0.0f32; d];
        for p in &parts {
            for (m, v) in manual.iter_mut().zip(p.to_dense(d)) {
                *m += v;
            }
        }
        for (a, b) in dense.iter().zip(&manual) {
            if (a - b).abs() > 1e-4 {
                return Err("nonlinear aggregation".into());
            }
        }

        // padded-pairs path scatters to the same dense vector
        let ktot = agg.total_entries() + g.usize_in(0, 5);
        let (idx, val) = agg.to_padded_pairs(ktot, 1.0);
        let mut scattered = vec![0.0f32; d];
        for (&i, &v) in idx.iter().zip(&val) {
            scattered[i as usize] += v;
        }
        for (a, b) in dense.iter().zip(&scattered) {
            if (a - b).abs() > 1e-4 {
                return Err("padded pairs mismatch".into());
            }
        }
        Ok(())
    });
}

/// The delta-downlink reconstruction invariant (DESIGN.md §9): for ANY
/// base generation, a single delta carrying the union of the per-round
/// changed-index sets since that base — with the *current* values at
/// those indices — patches the base snapshot into the head model
/// **bit-for-bit**, and the incrementally-maintained content digest
/// equals the from-scratch digest of the head. This is exactly what the
/// PS's generation ring + `encode_delta_frame` send and what the
/// worker's `apply_delta_in_place` verifies.
#[test]
fn delta_apply_over_any_generation_gap_matches_dense_model() {
    use ragek::fl::codec::params_digest;
    use ragek::fl::transport::apply_delta_in_place;
    prop_check("delta-gap-reconstruction", 100, |g| {
        let d = g.usize_in(4, 300);
        let rounds = g.usize_in(1, 20);
        let mut global = g.vec_f32(d, 1.0);
        // snapshots[b] = the model after b server updates; ring[b] = the
        // indices update b+1 touched (what the engine's delta ring holds)
        let mut snapshots = vec![global.clone()];
        let mut ring: Vec<Vec<u32>> = Vec::new();
        for _ in 0..rounds {
            let k = g.usize_in(1, d);
            let sel = g.vec_u32_distinct(d, k);
            for &j in &sel {
                global[j as usize] += g.f32_in(-1.0, 1.0);
            }
            ring.push(sel);
            snapshots.push(global.clone());
        }
        let base = g.usize_in(0, rounds);
        let mut union: Vec<u32> = ring[base..].iter().flatten().copied().collect();
        union.sort_unstable();
        union.dedup();
        let delta = SparseVec::new(
            union.clone(),
            union.iter().map(|&j| global[j as usize]).collect(),
        );
        let mut params = snapshots[base].clone();
        let digest = apply_delta_in_place(&mut params, params_digest(&snapshots[base]), &delta)
            .map_err(|e| format!("apply failed: {e:#}"))?;
        if params != global {
            return Err(format!("gap {} reconstruction diverged", rounds - base));
        }
        if digest != params_digest(&global) {
            return Err("incremental digest != from-scratch digest of the head".into());
        }
        // an empty delta (base == head, e.g. a just-resynced rejoiner) is
        // a no-op with an unchanged digest
        let empty = SparseVec::new(Vec::new(), Vec::new());
        let same = apply_delta_in_place(&mut params, digest, &empty)
            .map_err(|e| format!("empty apply failed: {e:#}"))?;
        if same != digest || params != global {
            return Err("empty delta must be a digest-preserving no-op".into());
        }
        // an out-of-range index must be rejected before any mutation
        let bad = SparseVec::new(vec![d as u32], vec![1.0]);
        if apply_delta_in_place(&mut params, digest, &bad).is_ok() {
            return Err("out-of-range delta index must be rejected".into());
        }
        if params != global {
            return Err("a rejected delta must leave the params untouched".into());
        }
        Ok(())
    });
}

#[test]
fn topk_abs_is_exact_against_sort() {
    prop_check("topk-exactness", 200, |g| {
        let d = g.usize_in(1, 800);
        let k = g.usize_in(0, d);
        // quantized values force ties
        let grad: Vec<f32> = g.vec_f32(d, 2.0).iter().map(|v| v.round()).collect();
        let got = topk_abs_sparse(&grad, k);
        let mut order: Vec<u32> = (0..d as u32).collect();
        order.sort_by(|&a, &b| {
            grad[b as usize]
                .abs()
                .partial_cmp(&grad[a as usize].abs())
                .unwrap()
                .then_with(|| a.cmp(&b))
        });
        if got.idx != order[..k] {
            return Err(format!("topk mismatch: {:?} vs {:?}", got.idx, &order[..k]));
        }
        for (&i, &v) in got.idx.iter().zip(&got.val) {
            if grad[i as usize] != v {
                return Err("value not the signed gradient entry".into());
            }
        }
        Ok(())
    });
}

#[test]
fn partition_covers_every_sample_exactly_once() {
    use ragek::data::partition::{partition, Scheme};
    use ragek::data::synth::synthetic_mnist;
    prop_check("partition-coverage", 12, |g| {
        let n = g.usize_in(50, 400);
        let n_clients = g.usize_in(1, 5) * 2;
        let ds = synthetic_mnist(g.case as u64, n);
        for scheme in [
            Scheme::PaperPairs,
            Scheme::Iid,
            Scheme::Dirichlet { alpha: 0.4 },
        ] {
            let parts = partition(&ds, n_clients, &scheme, g.case as u64);
            let mut seen = vec![0usize; n];
            for p in &parts {
                for &s in p {
                    seen[s] += 1;
                }
            }
            if seen.iter().any(|&c| c != 1) {
                return Err(format!("{scheme:?}: sample not covered exactly once"));
            }
        }
        Ok(())
    });
}

#[test]
fn frequency_similarity_is_scale_normalized() {
    use ragek::age::FrequencyVector;
    use ragek::clustering::connectivity_matrix;
    prop_check("similarity-normalization", 100, |g| {
        let d = 200;
        let k = g.usize_in(1, 20);
        let rounds = g.usize_in(1, 10);
        let mut f1 = FrequencyVector::new();
        let idxs: Vec<Vec<u32>> =
            (0..rounds).map(|_| g.vec_u32_distinct(d, k)).collect();
        for idx in &idxs {
            f1.record(idx);
        }
        // f2 records the same history twice as often (scaled client)
        let mut f2 = FrequencyVector::new();
        for _ in 0..2 {
            for idx in &idxs {
                f2.record(idx);
            }
        }
        let m = connectivity_matrix(&[f1, f2]);
        // d[1][2] = <f1, 2*f1>/<f1,f1> = 2; d[2][1] = 0.5
        if (m[0][1] - 2.0).abs() > 1e-9 || (m[1][0] - 0.5).abs() > 1e-9 {
            return Err(format!("normalization off: {} / {}", m[0][1], m[1][0]));
        }
        Ok(())
    });
}
