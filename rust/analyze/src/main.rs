//! Protocol conformance lints — `cargo run -p analyze`.
//!
//! Three source-level invariants that `rustc` cannot express, checked on
//! every CI run (DESIGN.md §13):
//!
//! 1. **Panic-free protocol edges.** The modules that sit on the wire —
//!    [`EDGE_MODULES`] — must not contain `.unwrap()`, `.expect(`,
//!    `panic!(`, `unreachable!(`, `todo!(` or `unimplemented!(` outside
//!    `#[cfg(test)]` blocks. A remote peer controls every byte those
//!    modules parse; a panic there is a remotely triggerable crash of
//!    the parameter server. Provably-infallible sites carry an escape
//!    hatch: `// analyze: allow(panic, <reason>)` on the same or the
//!    immediately preceding line. The reason is mandatory — a bare
//!    marker is itself a violation.
//! 2. **Wire-pin coverage.** Every variant of `Msg` (the whole wire
//!    vocabulary) must appear in the `every_variant()` fixture that
//!    feeds the `wire_bytes_never_encodes` pin test, so a new message
//!    type cannot ship without its arithmetic-size pin.
//! 3. **Knob documentation.** Every CLI option (`.opt`/`.flag` in
//!    `main.rs`) and every serialized config key
//!    (`ExperimentConfig::to_json`) must be mentioned in README.md or
//!    DESIGN.md — knobs that exist only in the source are knobs nobody
//!    tunes.
//!
//! Exit codes: 0 = clean, 1 = violations (printed one per line as
//! `file:line: [lint] message`), 2 = internal error (an anchor the
//! scanner keys on — `enum Msg {`, `fn to_json` — drifted, or a file is
//! unreadable). `--self-test` seeds known-bad snippets through the same
//! scanners and exits nonzero unless every seeded violation is caught
//! and every clean snippet passes, proving the lints have teeth.
//!
//! The scanner is deliberately line-based (strings and comments are
//! stripped with a small cross-line state machine) rather than a full
//! parser: it is zero-dependency, fast, and the failure mode of a
//! false positive is an escape-hatch comment, not a shipped panic.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// Modules on the wire: a remote peer reaches this code with attacker
/// controlled bytes, so they must never panic outside tests.
const EDGE_MODULES: &[&str] = &[
    "rust/src/fl/transport.rs",
    "rust/src/fl/codec.rs",
    "rust/src/fl/distributed.rs",
    "rust/src/fl/reactor.rs",
    "rust/src/fl/conn_fsm.rs",
    "rust/src/coordinator/server.rs",
];

const PANIC_TOKENS: &[&str] =
    &[".unwrap()", ".expect(", "panic!(", "unreachable!(", "todo!(", "unimplemented!("];

const ALLOW_MARKER: &str = "analyze: allow(panic";

struct Violation {
    file: String,
    line: usize,
    lint: &'static str,
    msg: String,
}

impl Violation {
    fn show(&self) -> String {
        format!("{}:{}: [{}] {}", self.file, self.line, self.lint, self.msg)
    }
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.iter().any(|a| a == "--self-test") {
        return self_test();
    }
    if let Some(unknown) = args.iter().find(|a| a.as_str() != "--self-test") {
        eprintln!("analyze: unknown argument {unknown:?} (only --self-test is accepted)");
        return ExitCode::from(2);
    }
    let root = repo_root();
    let mut violations = Vec::new();
    let mut internal = Vec::new();

    for rel in EDGE_MODULES {
        match read(&root, rel) {
            Ok(src) => violations.extend(lint_panics(rel, &src)),
            Err(e) => internal.push(e),
        }
    }
    match read(&root, "rust/src/fl/transport.rs") {
        Ok(src) => match lint_msg_coverage(&src) {
            Ok(v) => violations.extend(v),
            Err(e) => internal.push(e),
        },
        Err(e) => internal.push(e),
    }
    {
        let main_rs = read(&root, "rust/src/main.rs");
        let config_rs = read(&root, "rust/src/config/mod.rs");
        let readme = read(&root, "README.md");
        let design = read(&root, "DESIGN.md");
        match (main_rs, config_rs, readme, design) {
            (Ok(m), Ok(c), Ok(r), Ok(d)) => match lint_knob_docs(&m, &c, &r, &d) {
                Ok(v) => violations.extend(v),
                Err(e) => internal.push(e),
            },
            (m, c, r, d) => {
                for res in [m, c, r, d] {
                    if let Err(e) = res {
                        internal.push(e);
                    }
                }
            }
        }
    }

    if !internal.is_empty() {
        for e in &internal {
            eprintln!("analyze: internal error: {e}");
        }
        return ExitCode::from(2);
    }
    if violations.is_empty() {
        println!(
            "analyze: clean — {} edge modules panic-free, wire pin covers every Msg variant, \
             all knobs documented",
            EDGE_MODULES.len()
        );
        ExitCode::SUCCESS
    } else {
        for v in &violations {
            println!("{}", v.show());
        }
        println!("analyze: {} violation(s)", violations.len());
        ExitCode::from(1)
    }
}

/// The workspace root: this crate lives at `<root>/rust/analyze`.
fn repo_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("..")
}

fn read(root: &Path, rel: &str) -> Result<String, String> {
    std::fs::read_to_string(root.join(rel)).map_err(|e| format!("{rel}: {e}"))
}

// ---------------------------------------------------------------- cleaning

/// Cross-line scanner state: inside a `/* */` comment or a `"` string
/// that did not close on its line.
#[derive(Clone, Copy, Default)]
struct CleanState {
    in_block_comment: bool,
    in_string: bool,
}

/// Strip comments and literal *contents* from one line. String literals
/// keep their delimiting quotes (so `.expect("msg")` still reads
/// `.expect("")` and matches the token scan) but lose their interior, so
/// a string that merely *mentions* `.unwrap()` cannot trip the lint.
fn clean_line(line: &str, mut st: CleanState) -> (String, CleanState) {
    let b: Vec<char> = line.chars().collect();
    let mut out = String::new();
    let mut i = 0;
    while i < b.len() {
        if st.in_block_comment {
            if b[i] == '*' && i + 1 < b.len() && b[i + 1] == '/' {
                st.in_block_comment = false;
                i += 2;
            } else {
                i += 1;
            }
            continue;
        }
        if st.in_string {
            if b[i] == '\\' {
                i += 2;
            } else if b[i] == '"' {
                st.in_string = false;
                out.push('"');
                i += 1;
            } else {
                i += 1;
            }
            continue;
        }
        match b[i] {
            '/' if i + 1 < b.len() && b[i + 1] == '/' => break,
            '/' if i + 1 < b.len() && b[i + 1] == '*' => {
                st.in_block_comment = true;
                i += 2;
            }
            '"' => {
                out.push('"');
                st.in_string = true;
                i += 1;
            }
            'r' if i + 1 < b.len() && (b[i + 1] == '"' || b[i + 1] == '#') => {
                // Raw string r"..." / r#"..."# — assumed single-line,
                // which holds for every edge module (cross-line raw
                // strings would need the full lexer this tool avoids).
                let mut j = i + 1;
                let mut hashes = 0;
                while j < b.len() && b[j] == '#' {
                    hashes += 1;
                    j += 1;
                }
                if j < b.len() && b[j] == '"' {
                    j += 1;
                    'scan: while j < b.len() {
                        if b[j] == '"' {
                            let mut k = j + 1;
                            let mut seen = 0;
                            while k < b.len() && b[k] == '#' && seen < hashes {
                                seen += 1;
                                k += 1;
                            }
                            if seen == hashes {
                                j = k;
                                break 'scan;
                            }
                        }
                        j += 1;
                    }
                    out.push_str("\"\"");
                    i = j;
                } else {
                    out.push('r');
                    i += 1;
                }
            }
            '\'' => {
                // Char literal ('x', '\n', '\'') vs lifetime ('a in
                // &'a T): a literal closes within three chars.
                if i + 1 < b.len() && b[i + 1] == '\\' {
                    let mut j = i + 2;
                    while j < b.len() && b[j] != '\'' {
                        j += 1;
                    }
                    out.push_str("''");
                    i = j + 1;
                } else if i + 2 < b.len() && b[i + 2] == '\'' {
                    out.push_str("''");
                    i += 3;
                } else {
                    out.push('\'');
                    i += 1;
                }
            }
            c => {
                out.push(c);
                i += 1;
            }
        }
    }
    (out, st)
}

fn clean_all(src: &str) -> Vec<String> {
    let mut st = CleanState::default();
    src.lines()
        .map(|l| {
            let (c, next) = clean_line(l, st);
            st = next;
            c
        })
        .collect()
}

// ------------------------------------------------------------ lint: panics

/// Scan one edge module for panic tokens outside `#[cfg(test)]` blocks.
fn lint_panics(file: &str, src: &str) -> Vec<Violation> {
    let raw: Vec<&str> = src.lines().collect();
    let cleaned = clean_all(src);
    let mut out = Vec::new();

    let mut depth: i32 = 0;
    // Some(d): inside a #[cfg(test)] block; resume when depth returns to d.
    let mut skip_until: Option<i32> = None;
    // Saw #[cfg(test)]; the next `{` opens the excluded block.
    let mut armed = false;

    for (idx, clean) in cleaned.iter().enumerate() {
        let trimmed = clean.trim();
        if skip_until.is_none() && trimmed.starts_with("#[cfg(test)]") {
            armed = true;
        }
        let test_at_start = armed || skip_until.is_some();

        for c in clean.chars() {
            match c {
                '{' => {
                    if armed && skip_until.is_none() {
                        skip_until = Some(depth);
                    }
                    depth += 1;
                }
                '}' => {
                    depth -= 1;
                    if let Some(d) = skip_until {
                        if depth <= d {
                            skip_until = None;
                            armed = false;
                        }
                    }
                }
                _ => {}
            }
        }
        // `#[cfg(test)] use ...;` gates a single braceless item.
        if armed && skip_until.is_none() && trimmed.ends_with(';') {
            armed = false;
        }

        if test_at_start || skip_until.is_some() || armed {
            continue;
        }
        for token in PANIC_TOKENS {
            if !clean.contains(token) {
                continue;
            }
            let prev = idx.checked_sub(1).and_then(|p| raw.get(p).copied());
            match allow_marker(raw.get(idx).copied(), prev) {
                Marker::Valid => {}
                Marker::MissingReason => out.push(Violation {
                    file: file.into(),
                    line: idx + 1,
                    lint: "panic-free-edge",
                    msg: format!(
                        "`{token}` has a bare `// analyze: allow(panic)` marker — a reason is \
                         mandatory: `// analyze: allow(panic, <why this cannot fire>)`"
                    ),
                }),
                Marker::Absent => out.push(Violation {
                    file: file.into(),
                    line: idx + 1,
                    lint: "panic-free-edge",
                    msg: format!(
                        "`{token}` in a protocol-edge module outside #[cfg(test)]; return an \
                         error instead, or annotate why it cannot fire with \
                         `// analyze: allow(panic, <reason>)`"
                    ),
                }),
            }
            break; // one violation per line is enough signal
        }
    }
    out
}

enum Marker {
    Valid,
    MissingReason,
    Absent,
}

/// Look for `// analyze: allow(panic, reason)` on the flagged line or the
/// one above it (raw text — the marker lives in a comment).
fn allow_marker(same: Option<&str>, prev: Option<&str>) -> Marker {
    for line in [same, prev].into_iter().flatten() {
        if let Some(pos) = line.find(ALLOW_MARKER) {
            let rest = &line[pos + ALLOW_MARKER.len()..];
            let Some(close) = rest.find(')') else { return Marker::MissingReason };
            let reason = rest[..close].trim_start_matches(',').trim();
            return if reason.is_empty() { Marker::MissingReason } else { Marker::Valid };
        }
    }
    Marker::Absent
}

// ------------------------------------------------- lint: Msg pin coverage

/// Every `Msg` variant must appear in the `every_variant()` fixture that
/// the `wire_bytes_never_encodes` pin test iterates.
fn lint_msg_coverage(transport_src: &str) -> Result<Vec<Violation>, String> {
    let cleaned = clean_all(transport_src);
    let variants = enum_variants(&cleaned, "enum Msg")?;
    if variants.len() < 5 {
        return Err(format!(
            "enum Msg parse drifted: found only {} variants ({variants:?})",
            variants.len()
        ));
    }
    let fixture = item_body(&cleaned, "fn every_variant")
        .ok_or("transport.rs: `fn every_variant` fixture not found")?;
    let pin = item_body(&cleaned, "fn wire_bytes_never_encodes")
        .ok_or("transport.rs: `fn wire_bytes_never_encodes` pin test not found")?;

    let mut out = Vec::new();
    if !pin.contains("every_variant()") {
        out.push(Violation {
            file: "rust/src/fl/transport.rs".into(),
            line: 1,
            lint: "wire-pin-coverage",
            msg: "wire_bytes_never_encodes no longer iterates every_variant()".into(),
        });
    }
    for v in &variants {
        if !contains_ident(&fixture, &format!("Msg::{v}")) {
            out.push(Violation {
                file: "rust/src/fl/transport.rs".into(),
                line: 1,
                lint: "wire-pin-coverage",
                msg: format!(
                    "Msg::{v} is missing from every_variant(); every wire message needs its \
                     arithmetic-size pin in wire_bytes_never_encodes"
                ),
            });
        }
    }
    Ok(out)
}

/// Variant names of `enum <name> {` at nesting depth 1 inside the enum.
fn enum_variants(cleaned: &[String], anchor: &str) -> Result<Vec<String>, String> {
    let start = cleaned
        .iter()
        .position(|l| l.contains(anchor) && l.contains('{'))
        .ok_or_else(|| format!("anchor `{anchor} {{` not found"))?;
    let mut variants = Vec::new();
    let mut depth = 0i32;
    for line in &cleaned[start..] {
        let trimmed = line.trim();
        if depth == 1 {
            let name: String = trimmed
                .chars()
                .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
                .collect();
            if name.chars().next().is_some_and(|c| c.is_ascii_uppercase()) {
                let tail = trimmed[name.len()..].trim_start();
                if tail.is_empty()
                    || tail.starts_with('{')
                    || tail.starts_with('(')
                    || tail.starts_with(',')
                {
                    variants.push(name);
                }
            }
        }
        for c in line.chars() {
            match c {
                '{' => depth += 1,
                '}' => {
                    depth -= 1;
                    if depth == 0 {
                        return Ok(variants);
                    }
                }
                _ => {}
            }
        }
    }
    Err(format!("anchor `{anchor}` block never closed"))
}

/// The text of an item from its anchor line to its matching close brace.
fn item_body(cleaned: &[String], anchor: &str) -> Option<String> {
    let start = cleaned.iter().position(|l| l.contains(anchor))?;
    let mut depth = 0i32;
    let mut opened = false;
    let mut body = String::new();
    for line in &cleaned[start..] {
        body.push_str(line);
        body.push('\n');
        for c in line.chars() {
            match c {
                '{' => {
                    depth += 1;
                    opened = true;
                }
                '}' => depth -= 1,
                _ => {}
            }
        }
        if opened && depth <= 0 {
            return Some(body);
        }
    }
    None
}

/// `needle` occurs and is not a prefix of a longer path segment
/// (`Msg::Join` must not be satisfied by `Msg::JoinAck`).
fn contains_ident(haystack: &str, needle: &str) -> bool {
    let mut from = 0;
    while let Some(pos) = haystack[from..].find(needle) {
        let end = from + pos + needle.len();
        let boundary = haystack[end..]
            .chars()
            .next()
            .is_none_or(|c| !c.is_ascii_alphanumeric() && c != '_');
        if boundary {
            return true;
        }
        from = end;
    }
    false
}

// --------------------------------------------------- lint: knob docs

/// Every CLI knob and every serialized config key must be mentioned in
/// README.md or DESIGN.md.
fn lint_knob_docs(
    main_src: &str,
    config_src: &str,
    readme: &str,
    design: &str,
) -> Result<Vec<Violation>, String> {
    let cli = cli_knobs(main_src);
    if cli.len() < 10 {
        return Err(format!("main.rs CLI parse drifted: found only {} knobs", cli.len()));
    }
    let keys = to_json_keys(config_src)?;
    if keys.len() < 10 {
        return Err(format!("to_json parse drifted: found only {} keys", keys.len()));
    }
    let docs = format!("{readme}\n{design}");
    let mut out = Vec::new();
    for knob in &cli {
        if !docs_mention(&docs, &format!("--{knob}")) {
            out.push(Violation {
                file: "rust/src/main.rs".into(),
                line: 1,
                lint: "knob-docs",
                msg: format!("CLI option --{knob} is not documented in README.md or DESIGN.md"),
            });
        }
    }
    for key in &keys {
        let kebab = key.replace('_', "-");
        if !docs_mention(&docs, key) && !docs_mention(&docs, &format!("--{kebab}")) {
            out.push(Violation {
                file: "rust/src/config/mod.rs".into(),
                line: 1,
                lint: "knob-docs",
                msg: format!(
                    "config key `{key}` (to_json) is not documented in README.md or DESIGN.md"
                ),
            });
        }
    }
    Ok(out)
}

/// Names declared via `.opt("name", ...)` / `.flag("name", ...)`.
fn cli_knobs(main_src: &str) -> Vec<String> {
    let mut knobs = Vec::new();
    for call in [".opt(\"", ".flag(\""] {
        let mut from = 0;
        while let Some(pos) = main_src[from..].find(call) {
            let start = from + pos + call.len();
            let name: String = main_src[start..]
                .chars()
                .take_while(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || *c == '-')
                .collect();
            if !name.is_empty()
                && main_src[start + name.len()..].starts_with('"')
                && !knobs.contains(&name)
            {
                knobs.push(name);
            }
            from = start;
        }
    }
    knobs
}

/// Keys of `ExperimentConfig::to_json`: string literals opening a tuple —
/// `("key", ...` on one line, or a bare `"key",` directly after a line
/// ending in `(` (the multi-line tuple form rustfmt produces).
fn to_json_keys(config_src: &str) -> Result<Vec<String>, String> {
    let raw_lines: Vec<&str> = config_src.lines().collect();
    let cleaned = clean_all(config_src);
    let start = cleaned
        .iter()
        .position(|l| l.contains("fn to_json"))
        .ok_or("config/mod.rs: `fn to_json` not found")?;
    let mut depth = 0i32;
    let mut opened = false;
    let mut keys = Vec::new();
    for idx in start..cleaned.len() {
        let raw = raw_lines[idx].trim();
        let key = if let Some(rest) = raw.strip_prefix("(\"") {
            take_key(rest)
        } else if raw.starts_with('"') && idx > start && raw_lines[idx - 1].trim_end().ends_with('(')
        {
            take_key(&raw[1..])
        } else {
            None
        };
        if let Some(k) = key {
            if !keys.contains(&k) {
                keys.push(k);
            }
        }
        for c in cleaned[idx].chars() {
            match c {
                '{' => {
                    depth += 1;
                    opened = true;
                }
                '}' => depth -= 1,
                _ => {}
            }
        }
        if opened && depth <= 0 {
            return Ok(keys);
        }
    }
    Err("config/mod.rs: `fn to_json` block never closed".into())
}

/// `rest` starts just past the opening quote: read `key",` and return key.
fn take_key(rest: &str) -> Option<String> {
    let key: String = rest
        .chars()
        .take_while(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || *c == '_')
        .collect();
    (!key.is_empty() && rest[key.len()..].starts_with("\",")).then_some(key)
}

/// Word-boundary mention: the character on each side of the match is not
/// part of a knob name, so `--id` is not satisfied by `--io-timeout-ms`
/// and key `r` is not satisfied by the middle of a word.
fn docs_mention(docs: &str, needle: &str) -> bool {
    let is_word = |c: char| c.is_ascii_alphanumeric() || c == '_' || c == '-';
    let mut from = 0;
    while let Some(pos) = docs[from..].find(needle) {
        let at = from + pos;
        let before_ok = at == 0 || !docs[..at].chars().next_back().is_some_and(is_word);
        // A flag's own leading dashes must not fail the boundary check.
        let before_ok = before_ok || needle.starts_with('-');
        let after_ok = !docs[at + needle.len()..].chars().next().is_some_and(is_word);
        if before_ok && after_ok {
            return true;
        }
        from = at + needle.len();
    }
    false
}

// ------------------------------------------------------------- self-test

/// Seed known-bad and known-clean snippets through the real scanners and
/// verify the lints fire exactly where they must. Exits nonzero if any
/// seeded violation goes undetected — the CI step runs this before
/// trusting a clean report on the tree.
fn self_test() -> ExitCode {
    let mut failures = Vec::new();
    let mut check = |name: &str, ok: bool| {
        println!("self-test: {} {name}", if ok { "ok  " } else { "FAIL" });
        if !ok {
            failures.push(name.to_string());
        }
    };

    let seeded_bad = "pub fn f(x: Option<u32>) -> u32 {\n    x.unwrap()\n}\n\
                      pub fn g() { panic!(\"boom\"); }\n";
    let v = lint_panics("seeded.rs", seeded_bad);
    check("seeded .unwrap() and panic! are both caught", v.len() == 2);
    check("seeded violations would exit nonzero", !v.is_empty());

    let in_test = "#[cfg(test)]\nmod tests {\n    fn t() {\n        panic!(\"fine here\");\n    }\n}\n";
    check("#[cfg(test)] blocks are exempt", lint_panics("t.rs", in_test).is_empty());

    let allowed = "fn f(w: &[u8]) -> u32 {\n    \
                   // analyze: allow(panic, chunks_exact yields exact windows)\n    \
                   u32::from_le_bytes(w.try_into().unwrap())\n}\n";
    check("marker with a reason is honored", lint_panics("a.rs", allowed).is_empty());

    let bare = "fn f() {\n    // analyze: allow(panic)\n    None::<u32>.unwrap();\n}\n";
    let v = lint_panics("b.rs", bare);
    check(
        "bare marker without a reason is itself a violation",
        v.len() == 1 && v[0].msg.contains("reason is mandatory"),
    );

    let in_string = "fn f() {\n    let msg = \"never call .unwrap() here\";\n    drop(msg);\n}\n";
    check("tokens inside string literals are ignored", lint_panics("s.rs", in_string).is_empty());

    let in_comment = "fn f() {\n    // a stray panic!(...) in prose\n    /* .unwrap() too */\n}\n";
    check("tokens inside comments are ignored", lint_panics("c.rs", in_comment).is_empty());

    let synthetic_transport = "pub enum Msg {\n    Join { id: u32 },\n    Model { round: u32 },\n    \
         Report { id: u32 },\n    Request { round: u32 },\n    Update { id: u32 },\n    \
         Ghost { round: u32 },\n}\n\
         #[cfg(test)]\nmod tests {\n    fn every_variant() -> Vec<Msg> {\n        \
         vec![Msg::Join { id: 1 }, Msg::Model { round: 1 }, Msg::Report { id: 1 },\n             \
         Msg::Request { round: 1 }, Msg::Update { id: 1 }]\n    }\n    \
         fn wire_bytes_never_encodes() {\n        for m in every_variant() { drop(m); }\n    }\n}\n";
    match lint_msg_coverage(synthetic_transport) {
        Ok(v) => check(
            "a Msg variant missing from every_variant() is caught",
            v.len() == 1 && v[0].msg.contains("Msg::Ghost"),
        ),
        Err(e) => {
            println!("self-test: msg-coverage scanner errored: {e}");
            check("msg-coverage scanner runs on a synthetic enum", false);
        }
    }

    let main_src = ".opt(\"alpha\", \"\", \"x\").opt(\"beta-gamma\", \"\", \"x\")\
                    .opt(\"gone\", \"\", \"x\").flag(\"verbose\", \"x\")\
                    .opt(\"k1\", \"\", \"\").opt(\"k2\", \"\", \"\").opt(\"k3\", \"\", \"\")\
                    .opt(\"k4\", \"\", \"\").opt(\"k5\", \"\", \"\").opt(\"k6\", \"\", \"\")";
    let config_src = "fn to_json() {\n    x(vec![\n        (\"alpha\", 1),\n        (\n            \
         \"hidden_knob\",\n            2,\n        ),\n        (\"k1\", 0),\n        (\"k2\", 0),\n        \
         (\"k3\", 0),\n        (\"k4\", 0),\n        (\"k5\", 0),\n        (\"k6\", 0),\n        \
         (\"k7\", 0),\n        (\"k8\", 0),\n    ])\n}\n";
    let docs = "--alpha --beta-gamma --verbose hidden is not enough, hidden_knob is. \
                --k1 --k2 --k3 --k4 --k5 --k6 k7 k8 alpha";
    match lint_knob_docs(main_src, config_src, docs, "") {
        Ok(v) => {
            check(
                "an undocumented CLI knob is caught",
                v.iter().any(|x| x.msg.contains("--gone")),
            );
            check(
                "documented knobs pass (multi-line tuple keys included)",
                !v.iter().any(|x| x.msg.contains("hidden_knob") || x.msg.contains("--alpha")),
            );
        }
        Err(e) => {
            println!("self-test: knob scanner errored: {e}");
            check("knob scanner runs on a synthetic config", false);
        }
    }

    check("--id is not satisfied by --io-timeout-ms", {
        !docs_mention("--io-timeout-ms", "--id") && docs_mention("use --id here", "--id")
    });

    if failures.is_empty() {
        println!("self-test: all lints have teeth");
        ExitCode::SUCCESS
    } else {
        println!("self-test: {} check(s) FAILED — the lints are blind", failures.len());
        ExitCode::from(2)
    }
}
