//! Figures 2 + 3 driver: the paper's MNIST experiment.
//!
//! 10 clients, paired labels ({0,1}, {0,1}, {2,3}, {2,3}, ...), r=75,
//! k=10, H=4, M=20, Adam 1e-4. Runs rAge-k and rTop-k at identical (r,k)
//! bandwidth, dumps:
//!   * connectivity heatmaps at iterations 1/21/41/61 (Fig. 2),
//!   * accuracy + loss curves for both strategies (Fig. 3a/3b),
//! as CSVs under results/ plus terminal charts.
//!
//! ```sh
//! cargo run --release --example mnist_noniid [-- --rounds 150]
//! ```

use ragek::config::ExperimentConfig;
use ragek::coordinator::strategies::StrategyKind;
use ragek::fl::metrics::History;
use ragek::fl::trainer::Trainer;
use ragek::util::{argparse::ArgSpec, plot};

fn main() -> anyhow::Result<()> {
    let spec = ArgSpec::new("mnist_noniid", "paper MNIST experiment (Fig. 2 + 3)")
        .opt("rounds", "120", "global rounds")
        .opt("seed", "42", "experiment seed")
        .opt("out", "results", "output directory");
    let args: Vec<String> = std::env::args().skip(1).collect();
    let a = match spec.parse(&args) {
        Ok(a) => a,
        Err(ragek::util::argparse::ArgError::HelpRequested) => {
            println!("{}", spec.usage());
            return Ok(());
        }
        Err(e) => return Err(e.into()),
    };
    let outdir = std::path::PathBuf::from(a.get("out"));
    std::fs::create_dir_all(&outdir)?;

    let mut histories: Vec<History> = Vec::new();
    for strategy in [StrategyKind::RageK, StrategyKind::RTopK] {
        let mut cfg = ExperimentConfig::mnist_scaled();
        cfg.rounds = a.get_usize("rounds")?;
        cfg.seed = a.get_usize("seed")? as u64;
        cfg.strategy = strategy;
        // Fig. 3 is plotted on the global model: the paper's per-user
        // average saturates on 2-label shards regardless of strategy
        // (EXPERIMENTS.md §F3 discusses both metrics)
        cfg.eval_mode = ragek::config::EvalMode::Global;
        println!("\n=== {} ===", strategy.name());
        let mut trainer = Trainer::from_config(&cfg)?;
        if strategy == StrategyKind::RageK {
            // Fig. 2 snapshot cadence: iterations 1, 21, 41, 61
            trainer.heatmap_rounds =
                vec![1, 21, 41, 61].into_iter().filter(|&r| r <= cfg.rounds).collect();
        }
        let report = trainer.run()?;

        if strategy == StrategyKind::RageK {
            for (round, m) in &report.heatmaps {
                println!("\nFig. 2 — connectivity heatmap @ iteration {round}:");
                println!("{}", plot::heatmap(m, true));
                std::fs::write(
                    outdir.join(format!("fig2_heatmap_round{round}.csv")),
                    plot::matrix_csv(m),
                )?;
            }
            println!("ground truth pairs: {:?}", report.truth_labels);
            println!("clusters found:     {:?}", report.cluster_labels);
        }
        std::fs::write(
            outdir.join(format!("fig3_{}.csv", strategy.name().replace('/', "-"))),
            report.history.to_csv(),
        )?;
        histories.push(report.history);
    }

    let refs: Vec<&History> = histories.iter().collect();
    println!("\nFig. 3(a) — accuracy over rounds:");
    println!("{}", History::chart_accuracy(&refs, 70, 16));
    println!("Fig. 3(b) — training loss over rounds:");
    let loss_series: Vec<(&str, Vec<f64>)> =
        histories.iter().map(|h| (h.name.as_str(), h.loss_series())).collect();
    let loss_refs: Vec<(&str, &[f64])> =
        loss_series.iter().map(|(n, v)| (*n, v.as_slice())).collect();
    println!("{}", plot::line_chart(&loss_refs, 70, 16));

    for h in &histories {
        println!(
            "{:<10} final acc {:6.2}%   rounds-to-80% {:?}   uplink {:.2} MiB",
            h.name,
            h.final_accuracy() * 100.0,
            h.rounds_to_accuracy(0.80),
            h.comm.uplink() as f64 / (1 << 20) as f64,
        );
    }
    println!("\nCSVs under {}", outdir.display());
    Ok(())
}
