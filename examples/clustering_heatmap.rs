//! Focused Fig. 2 study: how fast does the eq. (3) + DBSCAN pipeline
//! recover the planted client pairs, and how does the heatmap sharpen
//! over rounds? Prints cluster-recovery statistics (Rand index against
//! the ground truth) alongside the heatmaps.
//!
//! ```sh
//! cargo run --release --example clustering_heatmap [-- --rounds 80]
//! ```

use ragek::config::ExperimentConfig;
use ragek::data::partition::paper_pair_truth;
use ragek::fl::trainer::Trainer;
use ragek::util::{argparse::ArgSpec, plot};

/// Rand index between two labelings (1.0 = identical partitions).
fn rand_index(a: &[usize], b: &[usize]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len();
    if n < 2 {
        return 1.0;
    }
    let mut agree = 0usize;
    let mut total = 0usize;
    for i in 0..n {
        for j in (i + 1)..n {
            let same_a = a[i] == a[j];
            let same_b = b[i] == b[j];
            if same_a == same_b {
                agree += 1;
            }
            total += 1;
        }
    }
    agree as f64 / total as f64
}

fn main() -> anyhow::Result<()> {
    let spec = ArgSpec::new("clustering_heatmap", "Fig. 2 clustering recovery study")
        .opt("rounds", "80", "global rounds")
        .opt("seed", "42", "experiment seed")
        .opt("snap-every", "10", "heatmap snapshot period");
    let args: Vec<String> = std::env::args().skip(1).collect();
    let a = match spec.parse(&args) {
        Ok(a) => a,
        Err(ragek::util::argparse::ArgError::HelpRequested) => {
            println!("{}", spec.usage());
            return Ok(());
        }
        Err(e) => return Err(e.into()),
    };

    let mut cfg = ExperimentConfig::mnist_scaled();
    cfg.rounds = a.get_usize("rounds")?;
    cfg.seed = a.get_usize("seed")? as u64;
    cfg.eval_every = 0; // clustering study only — skip eval cost

    let snap = a.get_usize("snap-every")?.max(1);
    let mut trainer = Trainer::from_config(&cfg)?;
    trainer.heatmap_rounds = (0..=cfg.rounds).step_by(snap).map(|r| r.max(1)).collect();
    let report = trainer.run()?;

    let truth = paper_pair_truth(cfg.n_clients);
    println!("ground truth pairs: {truth:?}\n");
    for (round, m) in &report.heatmaps {
        println!("connectivity @ round {round}:");
        println!("{}", plot::heatmap(m, true));
    }
    println!(
        "final clusters: {:?}  (Rand index vs truth: {:.3})",
        report.cluster_labels,
        rand_index(&report.cluster_labels, &truth)
    );
    println!("recluster log (round, clusters): {:?}", trainer.server().recluster_log);
    Ok(())
}
