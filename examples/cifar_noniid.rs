//! Figures 4 + 5 driver: the paper's CIFAR10 experiment on the PJRT
//! (XLA) backend.
//!
//! 6 clients, label blocks {0,1,2} / {3,4,5} / {6,7,8,9} assigned to
//! pairs, r=2500, k=100, Adam 1e-4 on the 2,515,338-parameter CNN of
//! Table I. H/M/batch/rounds are scaled down for the CPU testbed
//! (see EXPERIMENTS.md §F4/F5 for the mapping to the paper's values);
//! pass --rounds/--h to scale back up.
//!
//! Requires `make artifacts` first.
//!
//! ```sh
//! cargo run --release --example cifar_noniid [-- --rounds 30]
//! ```

use ragek::config::ExperimentConfig;
use ragek::coordinator::strategies::StrategyKind;
use ragek::fl::metrics::History;
use ragek::fl::trainer::Trainer;
use ragek::util::{argparse::ArgSpec, plot};

fn main() -> anyhow::Result<()> {
    let spec = ArgSpec::new("cifar_noniid", "paper CIFAR10 experiment (Fig. 4 + 5)")
        .opt("rounds", "16", "global rounds")
        .opt("h", "8", "local steps per round (paper: 100)")
        .opt("seed", "42", "experiment seed")
        .opt("train-n", "900", "synthetic train samples")
        .opt("out", "results", "output directory")
        .flag("ragek-only", "skip the rTop-k baseline run");
    let args: Vec<String> = std::env::args().skip(1).collect();
    let a = match spec.parse(&args) {
        Ok(a) => a,
        Err(ragek::util::argparse::ArgError::HelpRequested) => {
            println!("{}", spec.usage());
            return Ok(());
        }
        Err(e) => return Err(e.into()),
    };
    let outdir = std::path::PathBuf::from(a.get("out"));
    std::fs::create_dir_all(&outdir)?;

    let strategies: &[StrategyKind] = if a.get_flag("ragek-only") {
        &[StrategyKind::RageK]
    } else {
        &[StrategyKind::RageK, StrategyKind::RTopK]
    };

    let mut histories: Vec<History> = Vec::new();
    for &strategy in strategies {
        let mut cfg = ExperimentConfig::cifar_paper();
        cfg.rounds = a.get_usize("rounds")?;
        cfg.h = a.get_usize("h")?;
        cfg.recluster_every = (cfg.rounds / 3).max(2);
        cfg.seed = a.get_usize("seed")? as u64;
        cfg.train_n = a.get_usize("train-n")?;
        cfg.test_n = 320;
        cfg.eval_every = 2;
        cfg.strategy = strategy;
        cfg.eval_mode = ragek::config::EvalMode::Global; // see EXPERIMENTS.md §F5
        println!("\n=== {} (CNN d = {}) ===", strategy.name(), cfg.d());
        let mut trainer = Trainer::from_config(&cfg)?;
        if strategy == StrategyKind::RageK {
            // Fig. 4: snapshots at iteration 1 and after the first
            // reclustering window (paper: 1 and 201)
            trainer.heatmap_rounds = vec![1, cfg.recluster_every + 1];
        }
        let report = trainer.run()?;

        if strategy == StrategyKind::RageK {
            for (round, m) in &report.heatmaps {
                println!("\nFig. 4 — connectivity heatmap @ iteration {round}:");
                println!("{}", plot::heatmap(m, true));
                std::fs::write(
                    outdir.join(format!("fig4_heatmap_round{round}.csv")),
                    plot::matrix_csv(m),
                )?;
            }
            println!("ground truth pairs: {:?}", report.truth_labels);
            println!("clusters found:     {:?}", report.cluster_labels);
        }
        std::fs::write(
            outdir.join(format!("fig5_{}.csv", strategy.name().replace('/', "-"))),
            report.history.to_csv(),
        )?;
        histories.push(report.history);
    }

    if histories.len() > 1 {
        let refs: Vec<&History> = histories.iter().collect();
        println!("\nFig. 5(a) — accuracy over rounds:");
        println!("{}", History::chart_accuracy(&refs, 70, 16));
    }
    for h in &histories {
        println!(
            "{:<10} final acc {:6.2}%   uplink {:.2} MiB",
            h.name,
            h.final_accuracy() * 100.0,
            h.comm.uplink() as f64 / (1 << 20) as f64,
        );
    }
    Ok(())
}
