//! All-strategy shoot-out at equal k: rAge-k (both variants), rTop-k,
//! top-k, rand-k and dense on the paper's non-iid MNIST split, reporting
//! accuracy, uplink bytes, and uplink-to-target-accuracy — the
//! communication-efficiency trade-off the paper's §III argues.
//!
//! ```sh
//! cargo run --release --example strategy_comparison [-- --rounds 80]
//! ```

use ragek::config::ExperimentConfig;
use ragek::coordinator::strategies::StrategyKind;
use ragek::fl::metrics::History;
use ragek::fl::trainer::Trainer;
use ragek::util::argparse::ArgSpec;

fn main() -> anyhow::Result<()> {
    let spec = ArgSpec::new("strategy_comparison", "all strategies at equal k")
        .opt("rounds", "80", "global rounds")
        .opt("seed", "42", "experiment seed")
        .opt("target", "0.8", "accuracy target for bytes-to-accuracy")
        .flag("with-dense", "include the (slow, 4d-per-round) dense baseline");
    let args: Vec<String> = std::env::args().skip(1).collect();
    let a = match spec.parse(&args) {
        Ok(a) => a,
        Err(ragek::util::argparse::ArgError::HelpRequested) => {
            println!("{}", spec.usage());
            return Ok(());
        }
        Err(e) => return Err(e.into()),
    };
    let target = a.get_f64("target")? as f32;

    let mut strategies = vec![
        StrategyKind::RageK,
        StrategyKind::RageKIndependent,
        StrategyKind::RTopK,
        StrategyKind::TopK,
        StrategyKind::RandK,
    ];
    if a.get_flag("with-dense") {
        strategies.push(StrategyKind::Dense);
    }

    let mut histories: Vec<History> = Vec::new();
    for strategy in strategies {
        let mut cfg = ExperimentConfig::mnist_scaled();
        cfg.rounds = a.get_usize("rounds")?;
        cfg.seed = a.get_usize("seed")? as u64;
        cfg.strategy = strategy;
        cfg.eval_mode = ragek::config::EvalMode::Global;
        println!("=== {} ===", strategy.name());
        let mut trainer = Trainer::from_config(&cfg)?;
        histories.push(trainer.run()?.history);
    }

    let refs: Vec<&History> = histories.iter().collect();
    println!("\naccuracy over rounds:");
    println!("{}", History::chart_accuracy(&refs, 70, 18));

    println!(
        "{:<14} {:>10} {:>14} {:>18} {:>20}",
        "strategy", "final acc", "rounds->tgt", "uplink (MiB)", "uplink->tgt (MiB)"
    );
    for h in &histories {
        let fmt_bytes = |b: Option<u64>| {
            b.map(|x| format!("{:.2}", x as f64 / (1 << 20) as f64))
                .unwrap_or_else(|| "—".into())
        };
        println!(
            "{:<14} {:>9.2}% {:>14} {:>18.2} {:>20}",
            h.name,
            h.final_accuracy() * 100.0,
            h.rounds_to_accuracy(target)
                .map(|r| r.to_string())
                .unwrap_or_else(|| "—".into()),
            h.comm.uplink() as f64 / (1 << 20) as f64,
            fmt_bytes(h.uplink_to_accuracy(target)),
        );
    }
    Ok(())
}
