//! Ablations over the design choices DESIGN.md §4 calls out:
//!
//! * (r, k) sweep — the γ = k / (k + (r-k)β + (d-r)) compression
//!   trade-off of §II-A: larger r explores more but loosens the
//!   convergence constant;
//! * M (recluster period) sweep;
//! * DBSCAN eps sensitivity;
//! * age merge rule (min vs max).
//!
//! ```sh
//! cargo run --release --example ablation_rk [-- --rounds 60]
//! ```

use ragek::clustering::MergeRule;
use ragek::config::ExperimentConfig;
use ragek::fl::trainer::Trainer;
use ragek::util::argparse::ArgSpec;

fn run_one(mut cfg: ExperimentConfig, label: &str) -> anyhow::Result<()> {
    cfg.eval_every = cfg.rounds; // eval once at the end
    cfg.eval_mode = ragek::config::EvalMode::Global;
    let mut trainer = Trainer::from_config(&cfg)?;
    let report = trainer.run()?;
    println!(
        "{label:<34} acc {:6.2}%  clusters {:?}  uplink {:.2} MiB",
        report.final_accuracy * 100.0,
        report.cluster_labels,
        report.history.comm.uplink() as f64 / (1 << 20) as f64
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let spec = ArgSpec::new("ablation_rk", "r/k, M, eps and merge-rule ablations")
        .opt("rounds", "60", "global rounds per configuration")
        .opt("seed", "42", "experiment seed");
    let args: Vec<String> = std::env::args().skip(1).collect();
    let a = match spec.parse(&args) {
        Ok(a) => a,
        Err(ragek::util::argparse::ArgError::HelpRequested) => {
            println!("{}", spec.usage());
            return Ok(());
        }
        Err(e) => return Err(e.into()),
    };
    let rounds = a.get_usize("rounds")?;
    let seed = a.get_usize("seed")? as u64;
    let base = || {
        let mut c = ExperimentConfig::mnist_scaled();
        c.rounds = rounds;
        c.seed = seed;
        c
    };

    println!("-- (r, k) sweep (paper: r=75, k=10) --");
    for (r, k) in [(10usize, 10usize), (25, 10), (75, 10), (200, 10), (75, 5), (75, 25)] {
        let mut c = base();
        c.r = r;
        c.k = k;
        run_one(c, &format!("r={r:<4} k={k}"))?;
    }

    println!("\n-- recluster period M (paper: 20) --");
    for m in [0usize, 5, 20, 50] {
        let mut c = base();
        c.recluster_every = m;
        run_one(c, &format!("M={m} (0 = never recluster)"))?;
    }

    println!("\n-- DBSCAN eps (default 0.35) --");
    for eps in [0.1, 0.35, 0.6, 0.9] {
        let mut c = base();
        c.dbscan.eps = eps;
        run_one(c, &format!("eps={eps}"))?;
    }

    println!("\n-- age merge rule on cluster formation --");
    for (rule, name) in [(MergeRule::Min, "min (freshest wins)"), (MergeRule::Max, "max")] {
        let mut c = base();
        c.merge_rule = rule;
        run_one(c, name)?;
    }
    Ok(())
}
