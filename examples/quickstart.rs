//! Quickstart: train the paper's MNIST setup with rAge-k for a few dozen
//! rounds on the pure-Rust backend (no artifacts needed) and print the
//! accuracy curve.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use ragek::config::ExperimentConfig;
use ragek::fl::metrics::History;
use ragek::fl::trainer::Trainer;

fn main() -> anyhow::Result<()> {
    let mut cfg = ExperimentConfig::mnist_scaled();
    cfg.rounds = 60; // quick demo; the paper preset runs 150
    cfg.eval_every = 5;

    println!(
        "rAge-k quickstart: {} clients, r={}, k={}, H={}, M={} (d={})",
        cfg.n_clients, cfg.r, cfg.k, cfg.h, cfg.recluster_every, cfg.d()
    );

    let mut trainer = Trainer::from_config(&cfg)?;
    let report = trainer.run()?;

    println!("\naccuracy over rounds:");
    println!("{}", History::chart_accuracy(&[&report.history], 70, 14));
    println!(
        "final accuracy: {:.2}%   uplink: {:.2} MiB   clusters found: {:?}",
        report.final_accuracy * 100.0,
        report.history.comm.uplink() as f64 / (1 << 20) as f64,
        report.cluster_labels
    );
    if let Some(truth) = &report.truth_labels {
        println!("ground-truth pairs:              {truth:?}");
    }
    Ok(())
}
